//! Multi-tenant `SolverService` concurrency stress: N client threads
//! firing seeded mixed workloads (every `Scheme` × `OpKind`, widths from
//! `STENCILWAVE_THREADS`) at one service, every result asserted
//! bit-identical to a private serial per-job reference. Tenancy changes
//! scheduling — which window a job lands on, what it batches with —
//! never numerics. The stats invariants ride along: no claim ever finds
//! a busy group (the oversubscription guard), every accepted job
//! completes, and a staged storm of identical small jobs batches
//! deterministically.

mod common;

use std::thread;

use common::{
    tenant_grids, tenant_jobs, tenant_reference, tenant_service_shape, thread_counts, Gen,
};
use stencilwave::coordinator::service::{JobSpec, JobTicket, ServiceConfig, SolverService};

#[test]
fn concurrent_clients_stay_bit_exact() {
    let widths = thread_counts();
    for clients in [2usize, 4] {
        let per_client = 5usize;
        let mut gen = Gen(0x57E55 + clients as u64);
        let jobs = tenant_jobs(&mut gen, clients * per_client, &widths);
        let mut svc = SolverService::new(tenant_service_shape(&jobs, 4)).unwrap();
        thread::scope(|s| {
            for (c, chunk) in jobs.chunks(per_client).enumerate() {
                let svc = &svc;
                s.spawn(move || {
                    for job in chunk {
                        let (f, u0, h2) = tenant_grids(&job.cfg, job.seed);
                        let out = svc
                            .run_job(JobSpec::new(job.cfg.clone(), u0).rhs(f, h2))
                            .unwrap_or_else(|e| {
                                panic!("client {c} {:?} x {:?}: {e:#}", job.cfg.scheme, job.cfg.op)
                            });
                        let want = tenant_reference(&job.cfg, job.seed);
                        assert_eq!(
                            out.u.max_abs_diff(&want),
                            0.0,
                            "client {c}: {:?} x {:?} under tenancy vs private serial run",
                            job.cfg.scheme,
                            job.cfg.op
                        );
                    }
                });
            }
        });
        let stats = svc.stats();
        assert_eq!(stats.submitted, (clients * per_client) as u64);
        assert_eq!(stats.completed, stats.submitted, "every accepted job completes");
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.claim_conflicts, 0, "no claim may find a busy group");
        assert!(stats.peak_groups_busy <= svc.group_count());
        svc.shutdown();
    }
}

#[test]
fn pipelined_submissions_overlap_and_stay_bit_exact() {
    // submit-all-then-wait from one client: windows run concurrently
    // inside the service itself (distinct configs so batching cannot
    // serialize them), results redeemed out of submission order
    let widths = thread_counts();
    let mut gen = Gen(0x0F_F10AD);
    let jobs = tenant_jobs(&mut gen, 10, &widths);
    let mut svc = SolverService::new(tenant_service_shape(&jobs, 4)).unwrap();
    let tickets: Vec<JobTicket> = jobs
        .iter()
        .map(|job| {
            let (f, u0, h2) = tenant_grids(&job.cfg, job.seed);
            svc.submit(JobSpec::new(job.cfg.clone(), u0).rhs(f, h2)).unwrap()
        })
        .collect();
    for (job, t) in jobs.iter().zip(tickets).rev() {
        let out = t.wait().unwrap();
        let want = tenant_reference(&job.cfg, job.seed);
        assert_eq!(out.u.max_abs_diff(&want), 0.0, "{:?} x {:?}", job.cfg.scheme, job.cfg.op);
    }
    let stats = svc.stats();
    assert_eq!(stats.completed, 10);
    assert_eq!(stats.claim_conflicts, 0);
    svc.shutdown();
}

#[test]
fn a_staged_storm_of_identical_small_jobs_batches_exactly() {
    // twelve tenants share one config (batch-eligible by size) but own
    // distinct seeded grids; staging them behind pause() makes the batch
    // split deterministic — max_batch mates ride the first window, the
    // remainder the second — and every tenant still gets its own bits
    let widths = thread_counts();
    let mut gen = Gen(0xBA7C);
    let lead = tenant_jobs(&mut gen, 1, &widths).remove(0);
    let seeds: Vec<u64> = (0..12).map(|_| gen.next()).collect();
    let shape = ServiceConfig { max_batch: 8, ..tenant_service_shape(&[lead.clone()], 4) };
    assert!(
        {
            let (nz, ny, nx) = lead.cfg.size;
            nz * ny * nx <= shape.batch_cells
        },
        "generated parity grids must stay batch-eligible"
    );
    let mut svc = SolverService::new(shape).unwrap();
    svc.pause();
    let tickets: Vec<JobTicket> = seeds
        .iter()
        .map(|&seed| {
            let (f, u0, h2) = tenant_grids(&lead.cfg, seed);
            svc.submit(JobSpec::new(lead.cfg.clone(), u0).rhs(f, h2)).unwrap()
        })
        .collect();
    svc.resume();
    let mut batch_sizes: Vec<usize> = Vec::new();
    for (&seed, t) in seeds.iter().zip(tickets) {
        let out = t.wait().unwrap();
        batch_sizes.push(out.batch_size);
        let want = tenant_reference(&lead.cfg, seed);
        assert_eq!(out.u.max_abs_diff(&want), 0.0, "batched tenant seed {seed:#x}");
    }
    batch_sizes.sort_unstable();
    assert_eq!(batch_sizes, [vec![4usize; 4], vec![8usize; 8]].concat());
    let stats = svc.stats();
    assert_eq!(stats.batches, 2, "12 staged mates split 8 + 4");
    assert_eq!(stats.batched_jobs, 12);
    assert_eq!(stats.completed, 12);
    assert_eq!(stats.claim_conflicts, 0);
    svc.shutdown();
}

#[test]
fn a_wide_job_is_not_starved_by_a_live_narrow_stream() {
    // the seed scheduler's oldest-runnable scan starved exactly this
    // shape: a whole-machine-wide job queued while a stream of
    // single-group jobs keeps at least one window busy is passed over
    // on every claim, indefinitely. The aging rule bounds it: after
    // `age_after` passed-over cycles the wide job reserves its window
    // and nothing younger can leapfrog it. A live feeder thread keeps
    // the narrow pressure up until the wide job actually finishes.
    use std::sync::atomic::{AtomicBool, Ordering};
    let age_after = 4u64;
    let shape = ServiceConfig {
        groups: 2,
        group_width: 1,
        max_batch: 1, // every claim is its own cycle
        age_after,
        queue_capacity: 256,
        ..Default::default()
    };
    // narrow: inline baseline (one group); wide: a t = 2 wavefront team
    // spanning both single-worker groups
    let narrow = common::parity_config(
        stencilwave::config::Scheme::JacobiBaseline,
        stencilwave::stencil::op::OpKind::ConstLaplace7,
        1,
    );
    let wide = common::parity_config(
        stencilwave::config::Scheme::JacobiWavefront,
        stencilwave::stencil::op::OpKind::ConstLaplace7,
        2,
    );
    let mut svc = SolverService::new(shape).unwrap();
    svc.pause();
    let mut narrow_tickets: Vec<JobTicket> = Vec::new();
    for i in 0..4u64 {
        let (f, u0, h2) = tenant_grids(&narrow, i);
        narrow_tickets.push(svc.submit(JobSpec::new(narrow.clone(), u0).rhs(f, h2)).unwrap());
    }
    let (f, u0, h2) = tenant_grids(&wide, 0xA1DE);
    let wide_ticket = svc.submit(JobSpec::new(wide.clone(), u0).rhs(f, h2)).unwrap();
    svc.resume();
    let wide_done = AtomicBool::new(false);
    let (skipped, fed) = thread::scope(|s| {
        let feeder = {
            let svc = &svc;
            let narrow = &narrow;
            let wide_done = &wide_done;
            s.spawn(move || {
                let mut tickets = Vec::new();
                let mut i = 100u64;
                while !wide_done.load(Ordering::Acquire) && tickets.len() < 150 {
                    let (f, u0, h2) = tenant_grids(narrow, i);
                    tickets
                        .push(svc.submit(JobSpec::new(narrow.clone(), u0).rhs(f, h2)).unwrap());
                    i += 1;
                }
                tickets
            })
        };
        let out = wide_ticket.wait().expect("the wide job must complete, not starve");
        wide_done.store(true, Ordering::Release);
        let fed = feeder.join().unwrap();
        assert_eq!(out.u.max_abs_diff(&tenant_reference(&wide, 0xA1DE)), 0.0);
        (out.skipped_cycles, fed)
    });
    assert!(
        skipped <= age_after + 2,
        "wide job passed over {skipped} cycles under live load (age_after {age_after} + slack 2)"
    );
    let fed_count = fed.len();
    for t in narrow_tickets.into_iter().chain(fed) {
        t.wait().unwrap();
    }
    let stats = svc.stats();
    assert_eq!(stats.completed, 5 + fed_count as u64, "every accepted job still completes");
    assert_eq!(stats.claim_conflicts, 0);
    svc.shutdown();
}

#[test]
fn shutdown_under_load_drains_every_outstanding_ticket() {
    // shut down while jobs are queued and in flight: every ticket
    // already handed out is still honored bit-exactly (the drain
    // guarantee under load, not just on an idle queue), and the next
    // submit is the typed "shut down" rejection — never a hang, never a
    // dropped ticket
    let widths = thread_counts();
    let mut gen = Gen(0xD2A1A);
    let jobs = tenant_jobs(&mut gen, 8, &widths);
    let mut svc = SolverService::new(tenant_service_shape(&jobs, 4)).unwrap();
    let tickets: Vec<JobTicket> = jobs
        .iter()
        .map(|job| {
            let (f, u0, h2) = tenant_grids(&job.cfg, job.seed);
            svc.submit(JobSpec::new(job.cfg.clone(), u0).rhs(f, h2)).unwrap()
        })
        .collect();
    svc.shutdown();
    for (job, t) in jobs.iter().zip(tickets) {
        let out = t.wait().expect("accepted jobs survive the drain");
        let want = tenant_reference(&job.cfg, job.seed);
        assert_eq!(out.u.max_abs_diff(&want), 0.0, "{:?} x {:?}", job.cfg.scheme, job.cfg.op);
    }
    let stats = svc.stats();
    assert_eq!(stats.completed, 8);
    assert_eq!(stats.failed, 0);
    let job = &jobs[0];
    let (f, u0, h2) = tenant_grids(&job.cfg, job.seed);
    let err = svc.submit(JobSpec::new(job.cfg.clone(), u0).rhs(f, h2)).map(|_| ()).unwrap_err();
    assert!(err.to_string().contains("shut down"), "{err:#}");
}
