//! Integration suite for the unified `Solver` session API:
//!
//! * builder rejects invalid configs with the *same* errors
//!   `RunConfig::validate()` gives;
//! * a session reused across `run()` calls — and a pool reused across
//!   sessions and schemes — stays bit-exact vs the serial references;
//! * a built session spawns no new threads across `run()` calls
//!   (team-size accounting);
//! * `PinPolicy` is advisory and a no-op off-Linux;
//! * concurrent sessions on caller threads run side by side without
//!   cross-talk (each session owns its team — no process-wide mutex).

use stencilwave::config::{RunConfig, Scheme};
use stencilwave::coordinator::affinity::{pin_current_thread, PinPolicy};
use stencilwave::coordinator::solver::Solver;
use stencilwave::coordinator::wavefront::serial_reference;
use stencilwave::stencil::gauss_seidel::gs_sweeps;
use stencilwave::stencil::grid::Grid3;

fn cfg(scheme: Scheme) -> RunConfig {
    RunConfig { scheme, size: (12, 14, 10), t: 4, groups: 2, iters: 8, ..Default::default() }
}

#[test]
fn builder_errors_match_validate_errors() {
    // every invalid config class the old entry points rejected
    let mut odd_t = cfg(Scheme::JacobiWavefront);
    odd_t.t = 3;
    let mut bad_iters = cfg(Scheme::JacobiWavefront);
    bad_iters.iters = 6;
    let mut tiny = cfg(Scheme::GsBaseline);
    tiny.size = (2, 2, 2);
    let mut narrow = cfg(Scheme::JacobiMultiGroup);
    narrow.groups = 50;
    let mut unknown_machine = cfg(Scheme::JacobiBaseline);
    unknown_machine.machine = Some("pentium4".into());
    for bad in [odd_t, bad_iters, tiny, narrow, unknown_machine] {
        let want = bad.validate().unwrap_err().to_string();
        let have = Solver::builder(&bad).build().map(|_| ()).unwrap_err().to_string();
        assert_eq!(have, want, "builder must surface validate()'s error");
    }
}

#[test]
fn sessions_are_bit_exact_for_every_scheme() {
    let (nz, ny, nx) = (12, 14, 10);
    let f = Grid3::random(nz, ny, nx, 3);
    for scheme in Scheme::ALL {
        let c = cfg(scheme);
        let mut solver = Solver::builder(&c).rhs(f.clone(), 1.0).build().unwrap();
        let u0 = Grid3::random(nz, ny, nx, 17);
        let mut u = u0.clone();
        solver.run(&mut u, c.iters).unwrap();
        let want = solver.reference(&u0, c.iters);
        assert_eq!(u.max_abs_diff(&want), 0.0, "{scheme:?}");
        // the runner's reference must itself match the plain serial sweeps
        let independent = if scheme.is_gs() {
            let mut r = u0.clone();
            gs_sweeps(&mut r, c.iters, c.gs_kernel());
            r
        } else {
            serial_reference(&u0, &f, 1.0, c.iters)
        };
        assert_eq!(want.max_abs_diff(&independent), 0.0, "{scheme:?} reference");
    }
}

#[test]
fn one_session_reused_across_runs_stays_exact_and_spawns_nothing() {
    let c = cfg(Scheme::JacobiWavefront);
    let f = Grid3::random(12, 14, 10, 4);
    let mut solver = Solver::builder(&c).rhs(f.clone(), 0.9).build().unwrap();
    let team = solver.team_size();
    assert_eq!(team, c.t, "the full team exists right after build()");
    for round in 0..4 {
        let u0 = Grid3::random(12, 14, 10, 30 + round);
        let mut u = u0.clone();
        solver.run(&mut u, 8).unwrap();
        let want = serial_reference(&u0, &f, 0.9, 8);
        assert_eq!(u.max_abs_diff(&want), 0.0, "round {round}");
    }
    // pool workers are never retired, so an unchanged team size proves no
    // run() call spawned a thread
    assert_eq!(solver.team_size(), team, "no growth across run() calls");
}

#[test]
fn one_pool_chained_through_sessions_of_every_scheme() {
    let (nz, ny, nx) = (12, 14, 10);
    let f = Grid3::random(nz, ny, nx, 5);
    let mut pool = None;
    for (i, scheme) in Scheme::ALL.into_iter().enumerate() {
        let c = cfg(scheme);
        let mut b = Solver::builder(&c).rhs(f.clone(), 1.0);
        if let Some(p) = pool.take() {
            b = b.pool(p);
        }
        let mut solver = b.build().unwrap();
        let u0 = Grid3::random(nz, ny, nx, 50 + i as u64);
        let mut u = u0.clone();
        solver.run(&mut u, c.iters).unwrap();
        let want = solver.reference(&u0, c.iters);
        assert_eq!(u.max_abs_diff(&want), 0.0, "{scheme:?} on the chained pool");
        pool = Some(solver.into_pool());
    }
    // the chained pool holds the largest team any scheme needed:
    // GsWavefront's sweeps x width = 4 * 2
    assert_eq!(pool.unwrap().size(), 8);
}

#[test]
fn step_advances_by_the_natural_pass() {
    let c = cfg(Scheme::JacobiMultiGroup);
    let f = Grid3::random(12, 14, 10, 6);
    let mut solver = Solver::builder(&c).rhs(f.clone(), 1.0).build().unwrap();
    assert_eq!(solver.step_iters(), c.t);
    let u0 = Grid3::random(12, 14, 10, 7);
    let mut u = u0.clone();
    solver.step(&mut u).unwrap();
    let want = serial_reference(&u0, &f, 1.0, c.t);
    assert_eq!(u.max_abs_diff(&want), 0.0);
}

#[test]
fn pin_policy_is_a_noop_where_unsupported_and_advisory_elsewhere() {
    // the backend must never fail a session: pinned builds run bit-exact
    // whether or not the kernel honored the mask
    for pin in [PinPolicy::None, PinPolicy::Compact, PinPolicy::Scatter] {
        let mut c = cfg(Scheme::JacobiWavefront);
        c.pin = pin;
        c.machine = Some("Nehalem EP".into()); // cache-group-aware topology
        let f = Grid3::random(12, 14, 10, 8);
        let mut solver = Solver::builder(&c).rhs(f.clone(), 1.0).build().unwrap();
        let u0 = Grid3::random(12, 14, 10, 9);
        let mut u = u0.clone();
        solver.run(&mut u, 8).unwrap();
        let want = serial_reference(&u0, &f, 1.0, 8);
        assert_eq!(u.max_abs_diff(&want), 0.0, "{pin:?}");
    }
    // off-Linux the raw backend reports failure instead of pretending
    if cfg!(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))
    {
        assert!(!pin_current_thread(0));
    }
}

/// The pre-0.2.0 convenience API serialized every caller on one global
/// mutexed pool; sessions own their team, so concurrent callers must all
/// complete and stay bit-exact (a deadlock or cross-talk here is the
/// regression).
#[test]
fn concurrent_sessions_do_not_serialize_or_cross_talk() {
    let threads = 4;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for seed in 0..threads {
            handles.push(scope.spawn(move || {
                let f = Grid3::random(10, 9, 8, 100 + seed);
                let u0 = Grid3::random(10, 9, 8, 200 + seed);
                let want = serial_reference(&u0, &f, 1.0, 8);
                let c = RunConfig {
                    scheme: Scheme::JacobiWavefront,
                    size: (10, 9, 8),
                    t: 4,
                    iters: 8,
                    ..Default::default()
                };
                let mut solver = Solver::builder(&c).rhs(f.clone(), 1.0).build().unwrap();
                for _ in 0..3 {
                    let mut u = u0.clone();
                    solver.run(&mut u, 8).unwrap();
                    assert_eq!(u.max_abs_diff(&want), 0.0, "caller {seed}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });
}
