//! Cache-simulator verification of the paper's central traffic claims.
//!
//! These tests drive exact schedule traces through the set-associative
//! hierarchy simulator and check what the ECM model *assumes*: that the
//! wavefront scheme keeps intermediate planes in the shared outer cache
//! and thereby divides memory traffic by the blocking factor.

use stencilwave::simulator::cache::Hierarchy;
use stencilwave::simulator::trace::{
    jacobi_steps_trace, jacobi_sweep_trace, run_trace, wavefront_jacobi_trace, Dims,
};

const D: Dims = Dims { nz: 34, ny: 32, nx: 32 };

fn hierarchy(cores: usize) -> Hierarchy {
    // one array ≈ 272 KB, three arrays stream; the t=4 rolling window
    // (~150 KB incl. tmp + rhs planes) fits the 384 KB OLC
    Hierarchy::uniform(cores, 8 << 10, 32 << 10, 384 << 10)
}

#[test]
fn baseline_moves_every_plane_through_memory() {
    let mut h = hierarchy(1);
    let mem = run_trace(&mut h, &jacobi_sweep_trace(D, false)) as f64;
    let per_lup = mem / D.interior() as f64;
    // load src + store dst (+ write allocate) with 3-plane reuse in cache:
    // must be within [16, 40] B/LUP
    assert!((14.0..=40.0).contains(&per_lup), "baseline {per_lup} B/LUP");
}

#[test]
fn t_sweeps_cost_t_times_one_sweep() {
    let t = 4;
    let mut h1 = hierarchy(1);
    let one = run_trace(&mut h1, &jacobi_sweep_trace(D, false)) as f64;
    let mut ht = hierarchy(1);
    let many = run_trace(&mut ht, &jacobi_steps_trace(D, t, false)) as f64;
    let ratio = many / one;
    assert!(
        (t as f64 * 0.8..=t as f64 * 1.2).contains(&ratio),
        "t sweeps should cost ~t× one sweep, got {ratio}"
    );
}

#[test]
fn wavefront_divides_memory_traffic() {
    for t in [2usize, 4] {
        let mut hb = hierarchy(1);
        let baseline = run_trace(&mut hb, &jacobi_steps_trace(D, t, false)) as f64;
        let mut hw = hierarchy(t);
        let wavefront = run_trace(&mut hw, &wavefront_jacobi_trace(D, t, false)) as f64;
        let reduction = baseline / wavefront;
        assert!(
            reduction > t as f64 * 0.45,
            "t={t}: traffic reduction only {reduction:.2}x (want ≳ {:.1}x)",
            t as f64 * 0.45
        );
    }
}

#[test]
fn wavefront_intermediates_live_in_shared_cache() {
    let mut h = hierarchy(4);
    run_trace(&mut h, &wavefront_jacobi_trace(D, 4, false));
    let stats = h.olc_stats();
    assert!(
        stats.hit_rate() > 0.5,
        "intermediate windows must hit the OLC: hit rate {:.2}",
        stats.hit_rate()
    );
}

#[test]
fn too_small_cache_defeats_temporal_blocking() {
    // With an OLC smaller than the rolling window, the wavefront's
    // advantage collapses — the capacity constraint behind the paper's
    // spatial blocking (Fig. 7) and our `choose_blocking`.
    let t = 4;
    let tiny = || Hierarchy::uniform(t, 2 << 10, 4 << 10, 16 << 10); // 16 KB OLC
    let mut hw = tiny();
    let wavefront = run_trace(&mut hw, &wavefront_jacobi_trace(D, t, false)) as f64;
    let mut hb = tiny();
    let baseline = run_trace(&mut hb, &jacobi_steps_trace(D, t, false)) as f64;
    let reduction = baseline / wavefront;
    assert!(
        reduction < t as f64 * 0.45,
        "a too-small OLC cannot sustain the full reduction: got {reduction:.2}x"
    );
}

#[test]
fn nt_stores_save_write_allocate_traffic() {
    let mut h_wa = hierarchy(1);
    let wa = run_trace(&mut h_wa, &jacobi_sweep_trace(D, false));
    let mut h_nt = hierarchy(1);
    let nt = run_trace(&mut h_nt, &jacobi_sweep_trace(D, true));
    let saved = wa as f64 - nt as f64;
    // one write-allocate line per store line: saving ≈ dst-array bytes
    let dst_bytes = (D.nz * D.ny * D.nx * 8) as f64;
    assert!(
        saved > 0.3 * dst_bytes,
        "NT stores must save ~the dst write-allocate: saved {saved:.0} of {dst_bytes:.0}"
    );
}
