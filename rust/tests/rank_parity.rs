//! Distributed rank-layer lockdown: the `Scheme::ALL` × `OpKind::ALL`
//! matrix at every rank count must be bit-exact with the single-rank
//! serial reference (remainder shard splits and radius-2 ops included),
//! faults must surface as typed `CommError`s instead of deadlocks, the
//! socket fabric must match shared memory, and the overlap counters
//! must show interior progress while an exchange is in flight.

mod common;

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use common::{
    assert_rank_matrix, assert_rank_parity, rank_counts, rank_parity_config, tenant_jobs_with, Gen,
};
use stencilwave::comm::{
    CommError, HaloExchange, Peer, SharedHaloStats, SocketTransport, Transport,
};
use stencilwave::config::{RunConfig, Scheme};
use stencilwave::coordinator::rank::{FabricKind, RankSet};
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::op::OpKind;

#[test]
fn rank_matrix_is_bit_exact() {
    // every scheme × op × rank count, uneven shard splits by
    // construction (see rank_parity_config); STENCILWAVE_RANKS pins the
    // counts in CI legs
    for ranks in rank_counts() {
        assert_rank_matrix(ranks, 0xD15C0 + ranks as u64);
    }
}

#[test]
fn seeded_tenant_mixes_hold_rank_parity() {
    // the same tenant-job generator that drives the service stress and
    // property suites, mapped through rank_parity_config: seeded mixed
    // workloads stay bit-exact at every rank count, with per-job seeds
    // (not one shared grid) so distinct tenants never alias
    let mut gen = Gen(0x7E4A11);
    for ranks in rank_counts() {
        for job in tenant_jobs_with(&mut gen, 4, &[ranks], rank_parity_config) {
            assert_rank_parity(&job.cfg, job.seed);
        }
    }
}

#[test]
fn radius2_deep_halos_survive_remainder_iters() {
    // the two sharpest corners at once: a radius-2 op under the deepest
    // halo rule (t·R = 8 ghost planes per side) and a GS scheme with an
    // odd sweep count that exercises the pipeline drain
    let jacobi = rank_parity_config(Scheme::JacobiMultiGroup, OpKind::Laplace13, 3);
    assert_rank_parity(&jacobi, 0xBEEF);
    let mut gs = rank_parity_config(Scheme::GsWavefront, OpKind::Laplace13, 3);
    gs.iters = 7;
    assert_rank_parity(&gs, 0xBEEF);
}

#[test]
fn a_dying_rank_surfaces_a_typed_comm_error() {
    let cfg = RunConfig {
        scheme: Scheme::JacobiWavefront,
        size: (22, 9, 8),
        t: 2,
        iters: 8,
        ranks: 3,
        ..Default::default()
    };
    let mut set = RankSet::builder(&cfg).build().unwrap();
    // kill the middle rank at the start of its second temporal block:
    // both neighbors are blocked on (or sending into) its endpoints
    set.set_fault(1, 2);
    let u0 = Grid3::random(22, 9, 8, 41);
    let mut u = u0.clone();
    let err = set.run(&mut u, 8).unwrap_err();
    let comm = err
        .downcast_ref::<CommError>()
        .unwrap_or_else(|| panic!("expected a typed CommError, got: {err:#}"));
    assert!(
        matches!(comm, CommError::Disconnected { .. }),
        "neighbors of a dead rank see Disconnected, got {comm:?}"
    );
    assert_eq!(u.max_abs_diff(&u0), 0.0, "no partial gather after a fault");
    // the set recovers: fabric is rebuilt, parity holds again
    set.clear_fault(1);
    set.run(&mut u, 8).unwrap();
    assert_eq!(u.max_abs_diff(&set.reference(&u0, 8)), 0.0);
}

#[test]
fn interior_progress_overlaps_in_flight_exchanges() {
    // two ranks, rank 1 slowed by a per-block compute delay: rank 0
    // races ahead, posts its halo, and must then wait (stalled); rank
    // 1's inbound halo lands *while it is still computing*, so its
    // receives find the message already delivered (overlapped). That
    // asymmetry is only possible if sends are posted asynchronously and
    // interior compute proceeds while the exchange is in flight.
    let cfg = RunConfig {
        scheme: Scheme::JacobiWavefront,
        size: (24, 9, 8),
        t: 2,
        iters: 8, // 4 temporal blocks -> 3 exchange rounds
        ranks: 2,
        ..Default::default()
    };
    let mut set = RankSet::builder(&cfg).build().unwrap();
    set.set_compute_delay(1, Duration::from_millis(40));
    let u0 = Grid3::random(24, 9, 8, 42);
    let mut u = u0.clone();
    set.run(&mut u, 8).unwrap();
    assert_eq!(u.max_abs_diff(&set.reference(&u0, 8)), 0.0, "skewed timing never changes bits");
    let stats = set.halo_stats();
    assert!(
        stats.overlapped_recvs >= 1,
        "slow rank must find halos already delivered mid-compute: {stats:?}"
    );
    assert!(
        stats.stalled_recvs >= 1,
        "fast rank must expose at least one wait on the slow rank: {stats:?}"
    );
    assert_eq!(stats.overlapped_recvs + stats.stalled_recvs, 2 * 3, "3 rounds, 2 receivers");
}

#[test]
fn socket_fabric_matches_shared_memory_bit_for_bit() {
    let cfg = rank_parity_config(Scheme::GsMultiGroup, OpKind::VarCoeff7, 2);
    let (nz, ny, nx) = cfg.size;
    let u0 = Grid3::random(nz, ny, nx, 43);
    let mut shared = u0.clone();
    RankSet::builder(&cfg).build().unwrap().run(&mut shared, cfg.iters).unwrap();
    let mut set = RankSet::builder(&cfg).fabric(FabricKind::SocketLocal).build().unwrap();
    let mut socket = u0.clone();
    match set.run(&mut socket, cfg.iters) {
        // sandboxes without loopback sockets skip, they don't fail
        Err(e)
            if e.downcast_ref::<CommError>().is_some_and(
                |c| matches!(c, CommError::Fabric(m) if m.starts_with("socket fabric")),
            ) =>
        {
            eprintln!("skipping socket-fabric parity (no loopback): {e}");
            return;
        }
        r => r.unwrap(),
    }
    assert_eq!(socket.max_abs_diff(&shared), 0.0, "wire framing must round-trip f64 bits");
}

// ---------------------------------------------------------------------------
// corrupt-frame negative coverage: a hostile or garbled wire must
// surface a typed CommError at the victim, never an unbounded
// allocation, a silent misparse, or a deadlocked rank

/// One loopback connection: the raw injector half (the test writes
/// arbitrary bytes into it) and the victim half a `SocketTransport`
/// endpoint is built on. `None` where the sandbox forbids sockets —
/// callers skip, matching the other socket tests.
fn loopback_injection_pair() -> Option<(TcpStream, TcpStream)> {
    let wired = (|| {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let injector = TcpStream::connect(listener.local_addr()?)?;
        let (victim, _) = listener.accept()?;
        injector.set_nodelay(true)?;
        Ok::<_, std::io::Error>((injector, victim))
    })();
    match wired {
        Ok(pair) => Some(pair),
        Err(e) => {
            eprintln!("skipping corrupt-frame test (no loopback): {e}");
            None
        }
    }
}

/// Encode one wire frame by hand: `[tag u64][len u64][payload f64...]`,
/// little-endian — with `len` free to lie about the payload.
fn raw_frame(tag: u64, claimed_len: u64, payload: &[f64]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(16 + payload.len() * 8);
    buf.extend_from_slice(&tag.to_le_bytes());
    buf.extend_from_slice(&claimed_len.to_le_bytes());
    for v in payload {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    buf
}

#[test]
fn oversized_frame_lengths_are_rejected_before_allocation() {
    // a header claiming more words than the receiver's halo limit —
    // including the u64::MAX case whose byte count overflows usize —
    // must come back as CommError::Frame carrying the offending
    // tag/len, and the poisoned stream must then read as Disconnected
    // (the reader stops; it cannot resynchronize past a rejected
    // header). Endpoint ids run over the STENCILWAVE_RANKS matrix.
    let limit = 8usize;
    for ranks in rank_counts() {
        let ranks = ranks.max(2);
        let rank = ranks - 1; // rightmost rank: its Left neighbor is the injector
        for hostile_len in [limit as u64 + 1, u64::MAX] {
            let Some((mut injector, victim)) = loopback_injection_pair() else { return };
            let mut ep =
                SocketTransport::from_stream(rank, ranks, Peer::Left, victim, limit).unwrap();
            injector.write_all(&raw_frame(3, hostile_len, &[])).unwrap();
            let err = ep.recv(Peer::Left).unwrap_err();
            assert_eq!(
                err,
                CommError::Frame {
                    rank,
                    peer: Peer::Left,
                    tag: 3,
                    len: hostile_len,
                    limit: limit as u64
                },
                "ranks {ranks}"
            );
            // anything after the rejected header is untrusted: typed
            // disconnect, not a hang and not a misparse
            assert_eq!(
                ep.recv(Peer::Left).unwrap_err(),
                CommError::Disconnected { rank, peer: Peer::Left }
            );
        }
    }
}

#[test]
fn truncated_payloads_surface_disconnected_not_deadlock() {
    // the header promises 4 words but the injector dies after 2: the
    // victim's blocked recv must wake with a typed Disconnected when
    // the stream ends mid-frame — never parse the short payload, never
    // wait forever
    for ranks in rank_counts() {
        let ranks = ranks.max(2);
        let rank = ranks - 1;
        let Some((mut injector, victim)) = loopback_injection_pair() else { return };
        let mut ep = SocketTransport::from_stream(rank, ranks, Peer::Left, victim, 64).unwrap();
        let t = std::thread::spawn(move || {
            injector.write_all(&raw_frame(0, 4, &[1.0, 2.0])).unwrap();
            injector.flush().unwrap();
            std::thread::sleep(Duration::from_millis(30));
            drop(injector); // EOF with the frame still 2 words short
        });
        let err = ep.recv(Peer::Left).unwrap_err();
        assert_eq!(err, CommError::Disconnected { rank, peer: Peer::Left }, "ranks {ranks}");
        t.join().unwrap();
    }
}

#[test]
fn non_monotone_tags_are_a_typed_protocol_error() {
    // a well-formed frame whose tag skips ahead of the watermark the
    // exchange engine expects: typed CommError::Protocol with both
    // tags, through the full socket decode path
    for ranks in rank_counts() {
        let ranks = ranks.max(2);
        let rank = ranks - 1;
        let Some((mut injector, victim)) = loopback_injection_pair() else { return };
        let ep = SocketTransport::from_stream(rank, ranks, Peer::Left, victim, 64).unwrap();
        let mut engine = HaloExchange::new(Box::new(ep), SharedHaloStats::new());
        injector.write_all(&raw_frame(7, 1, &[0.5])).unwrap();
        let err = engine.recv(Peer::Left).unwrap_err();
        assert_eq!(
            err,
            CommError::Protocol { rank, peer: Peer::Left, expected: 0, got: 7 },
            "ranks {ranks}"
        );
    }
}
