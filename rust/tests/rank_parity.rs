//! Distributed rank-layer lockdown: the `Scheme::ALL` × `OpKind::ALL`
//! matrix at every rank count must be bit-exact with the single-rank
//! serial reference (remainder shard splits and radius-2 ops included),
//! faults must surface as typed `CommError`s instead of deadlocks, the
//! socket fabric must match shared memory, and the overlap counters
//! must show interior progress while an exchange is in flight.

mod common;

use std::time::Duration;

use common::{
    assert_rank_matrix, assert_rank_parity, rank_counts, rank_parity_config, tenant_jobs_with, Gen,
};
use stencilwave::comm::CommError;
use stencilwave::config::{RunConfig, Scheme};
use stencilwave::coordinator::rank::{FabricKind, RankSet};
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::op::OpKind;

#[test]
fn rank_matrix_is_bit_exact() {
    // every scheme × op × rank count, uneven shard splits by
    // construction (see rank_parity_config); STENCILWAVE_RANKS pins the
    // counts in CI legs
    for ranks in rank_counts() {
        assert_rank_matrix(ranks, 0xD15C0 + ranks as u64);
    }
}

#[test]
fn seeded_tenant_mixes_hold_rank_parity() {
    // the same tenant-job generator that drives the service stress and
    // property suites, mapped through rank_parity_config: seeded mixed
    // workloads stay bit-exact at every rank count, with per-job seeds
    // (not one shared grid) so distinct tenants never alias
    let mut gen = Gen(0x7E4A11);
    for ranks in rank_counts() {
        for job in tenant_jobs_with(&mut gen, 4, &[ranks], rank_parity_config) {
            assert_rank_parity(&job.cfg, job.seed);
        }
    }
}

#[test]
fn radius2_deep_halos_survive_remainder_iters() {
    // the two sharpest corners at once: a radius-2 op under the deepest
    // halo rule (t·R = 8 ghost planes per side) and a GS scheme with an
    // odd sweep count that exercises the pipeline drain
    let jacobi = rank_parity_config(Scheme::JacobiMultiGroup, OpKind::Laplace13, 3);
    assert_rank_parity(&jacobi, 0xBEEF);
    let mut gs = rank_parity_config(Scheme::GsWavefront, OpKind::Laplace13, 3);
    gs.iters = 7;
    assert_rank_parity(&gs, 0xBEEF);
}

#[test]
fn a_dying_rank_surfaces_a_typed_comm_error() {
    let cfg = RunConfig {
        scheme: Scheme::JacobiWavefront,
        size: (22, 9, 8),
        t: 2,
        iters: 8,
        ranks: 3,
        ..Default::default()
    };
    let mut set = RankSet::builder(&cfg).build().unwrap();
    // kill the middle rank at the start of its second temporal block:
    // both neighbors are blocked on (or sending into) its endpoints
    set.set_fault(1, 2);
    let u0 = Grid3::random(22, 9, 8, 41);
    let mut u = u0.clone();
    let err = set.run(&mut u, 8).unwrap_err();
    let comm = err
        .downcast_ref::<CommError>()
        .unwrap_or_else(|| panic!("expected a typed CommError, got: {err:#}"));
    assert!(
        matches!(comm, CommError::Disconnected { .. }),
        "neighbors of a dead rank see Disconnected, got {comm:?}"
    );
    assert_eq!(u.max_abs_diff(&u0), 0.0, "no partial gather after a fault");
    // the set recovers: fabric is rebuilt, parity holds again
    set.clear_fault(1);
    set.run(&mut u, 8).unwrap();
    assert_eq!(u.max_abs_diff(&set.reference(&u0, 8)), 0.0);
}

#[test]
fn interior_progress_overlaps_in_flight_exchanges() {
    // two ranks, rank 1 slowed by a per-block compute delay: rank 0
    // races ahead, posts its halo, and must then wait (stalled); rank
    // 1's inbound halo lands *while it is still computing*, so its
    // receives find the message already delivered (overlapped). That
    // asymmetry is only possible if sends are posted asynchronously and
    // interior compute proceeds while the exchange is in flight.
    let cfg = RunConfig {
        scheme: Scheme::JacobiWavefront,
        size: (24, 9, 8),
        t: 2,
        iters: 8, // 4 temporal blocks -> 3 exchange rounds
        ranks: 2,
        ..Default::default()
    };
    let mut set = RankSet::builder(&cfg).build().unwrap();
    set.set_compute_delay(1, Duration::from_millis(40));
    let u0 = Grid3::random(24, 9, 8, 42);
    let mut u = u0.clone();
    set.run(&mut u, 8).unwrap();
    assert_eq!(u.max_abs_diff(&set.reference(&u0, 8)), 0.0, "skewed timing never changes bits");
    let stats = set.halo_stats();
    assert!(
        stats.overlapped_recvs >= 1,
        "slow rank must find halos already delivered mid-compute: {stats:?}"
    );
    assert!(
        stats.stalled_recvs >= 1,
        "fast rank must expose at least one wait on the slow rank: {stats:?}"
    );
    assert_eq!(stats.overlapped_recvs + stats.stalled_recvs, 2 * 3, "3 rounds, 2 receivers");
}

#[test]
fn socket_fabric_matches_shared_memory_bit_for_bit() {
    let cfg = rank_parity_config(Scheme::GsMultiGroup, OpKind::VarCoeff7, 2);
    let (nz, ny, nx) = cfg.size;
    let u0 = Grid3::random(nz, ny, nx, 43);
    let mut shared = u0.clone();
    RankSet::builder(&cfg).build().unwrap().run(&mut shared, cfg.iters).unwrap();
    let mut set = RankSet::builder(&cfg).fabric(FabricKind::SocketLocal).build().unwrap();
    let mut socket = u0.clone();
    match set.run(&mut socket, cfg.iters) {
        // sandboxes without loopback sockets skip, they don't fail
        Err(e)
            if e.downcast_ref::<CommError>().is_some_and(
                |c| matches!(c, CommError::Fabric(m) if m.starts_with("socket fabric")),
            ) =>
        {
            eprintln!("skipping socket-fabric parity (no loopback): {e}");
            return;
        }
        r => r.unwrap(),
    }
    assert_eq!(socket.max_abs_diff(&shared), 0.0, "wire framing must round-trip f64 bits");
}
