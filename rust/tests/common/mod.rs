//! Shared cross-scheme parity harness: the serial-reference /
//! bit-parity scaffolding formerly duplicated across `op_parity.rs`,
//! `schedules.rs` and `pool_reuse.rs`.
//!
//! The harness drives every case through a [`Solver`] session and
//! asserts the parallel result is bit-identical to the registry's serial
//! reference (and, for the paper's `ConstLaplace7` op, to the seed
//! kernels). [`assert_scheme_op_matrix`] walks `Scheme::ALL` ×
//! `OpKind::ALL`, so a future scheme or op variant cannot ship without
//! parity coverage. `STENCILWAVE_THREADS` (a count or a comma-separated
//! list) pins the parallel widths the matrix runs at — CI sweeps 1, 2
//! and 4. [`assert_rank_matrix`] is the distributed counterpart: the
//! same matrix through a [`RankSet`] of halo-exchange-coupled rank
//! sessions, rank counts pinned by `STENCILWAVE_RANKS`.
#![allow(dead_code)] // each integration-test crate uses a subset

use stencilwave::config::{RunConfig, Scheme};
use stencilwave::coordinator::rank::RankSet;
use stencilwave::coordinator::runner::runner_for;
use stencilwave::coordinator::service::ServiceConfig;
use stencilwave::coordinator::solver::Solver;
use stencilwave::stencil::gauss_seidel::{gs_sweeps, GsKernel};
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::jacobi::jacobi_steps;
use stencilwave::stencil::op::OpKind;

/// Deterministic pseudo-random case generator (xorshift).
pub struct Gen(pub u64);

impl Gen {
    pub fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next() as usize) % (hi - lo + 1)
    }
    pub fn pick<T: Copy>(&mut self, opts: &[T]) -> T {
        opts[(self.next() as usize) % opts.len()]
    }
}

/// Parallel widths the parity matrix runs at: `STENCILWAVE_THREADS`
/// (e.g. `4` or `1,2,4`) or the 1/2/4 default.
pub fn thread_counts() -> Vec<usize> {
    match std::env::var("STENCILWAVE_THREADS") {
        Ok(v) if !v.trim().is_empty() => v
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<usize>()
                    .unwrap_or_else(|e| panic!("STENCILWAVE_THREADS '{v}': {e}"))
                    .max(1)
            })
            .collect(),
        _ => vec![1, 2, 4],
    }
}

/// A valid `RunConfig` exercising `scheme` × `op` at parallel width
/// `threads`: scheme-specific `t`/`groups`/`iters` (odd iteration counts
/// where the scheme supports a remainder pass) and a radius-aware y
/// extent wide enough for the strictest block-width requirement, plus
/// one line so uneven splits appear.
pub fn parity_config(scheme: Scheme, op: OpKind, threads: usize) -> RunConfig {
    let threads = threads.max(1);
    let even = |n: usize| (n.max(2) + 1) & !1;
    let (t, groups, iters) = match scheme {
        Scheme::JacobiBaseline | Scheme::GsBaseline => (threads, 1, 3),
        Scheme::JacobiWavefront => (even(threads), 1, 2 * even(threads)),
        Scheme::JacobiMultiGroup => (4, threads, 8),
        // t = 2 keeps the diamond width rule (2R(t-1) lines per interval)
        // satisfiable at every STENCILWAVE_THREADS width on the ny below
        Scheme::JacobiDiamond => (2, threads, 6),
        Scheme::GsWavefront => (threads, 2, 2 * threads + 1),
        Scheme::GsMultiGroup => (3, threads, 7),
    };
    let r = op.radius();
    let ny = (2 * r + 2 * r * groups + 3).max(2 * r + 5);
    RunConfig { scheme, op, size: (11, ny, 9), t, groups, iters, ..Default::default() }
}

/// Run `cfg` through a `Solver` session and assert the result is
/// bit-identical to the registry's serial reference — and, for the
/// paper's `ConstLaplace7` op, to the seed `jacobi_steps`/`gs_sweeps`
/// kernels.
pub fn assert_bit_parity(cfg: &RunConfig, seed: u64) {
    let (nz, ny, nx) = cfg.size;
    let f = Grid3::random(nz, ny, nx, seed);
    let u0 = Grid3::random(nz, ny, nx, seed ^ 0xA5A5);
    let h2 = 0.9;
    let mut solver = Solver::builder(cfg).rhs(f.clone(), h2).build().unwrap();
    let mut u = u0.clone();
    solver.run(&mut u, cfg.iters).unwrap();
    let want = solver.reference(&u0, cfg.iters);
    let ctx = format!(
        "{:?} x {:?} {nz}x{ny}x{nx} t={} groups={} iters={}",
        cfg.scheme, cfg.op, cfg.t, cfg.groups, cfg.iters
    );
    assert_eq!(u.max_abs_diff(&want), 0.0, "{ctx}: parallel vs serial reference");
    if cfg.op == OpKind::ConstLaplace7 {
        let seed_want = seed_reference(cfg.scheme.is_gs(), &u0, &f, h2, cfg.iters);
        assert_eq!(u.max_abs_diff(&seed_want), 0.0, "{ctx}: parity with the seed kernels");
    }
}

/// The full `Scheme::ALL` × `OpKind::ALL` matrix at one parallel width.
pub fn assert_scheme_op_matrix(threads: usize, seed: u64) {
    for scheme in Scheme::ALL {
        for op in OpKind::ALL {
            assert_bit_parity(&parity_config(scheme, op, threads), seed);
        }
    }
}

/// Rank counts the distributed parity matrix runs at:
/// `STENCILWAVE_RANKS` (e.g. `2` or `1,2,3`) or the 1/2/3 default.
pub fn rank_counts() -> Vec<usize> {
    match std::env::var("STENCILWAVE_RANKS") {
        Ok(v) if !v.trim().is_empty() => v
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<usize>()
                    .unwrap_or_else(|e| panic!("STENCILWAVE_RANKS '{v}': {e}"))
                    .max(1)
            })
            .collect(),
        _ => vec![1, 2, 3],
    }
}

/// A valid `RunConfig` exercising `scheme` × `op` across `ranks` z
/// shards: modest in-rank parallelism, odd iteration counts where the
/// scheme allows a remainder pass, and a z extent of
/// `2R + ranks · depth + ranks + 1` — every rank clears the halo-depth
/// floor *and* one plane of remainder makes the shard split uneven.
pub fn rank_parity_config(scheme: Scheme, op: OpKind, ranks: usize) -> RunConfig {
    let (t, groups, iters) = match scheme {
        Scheme::JacobiBaseline | Scheme::GsBaseline => (2, 1, 3),
        Scheme::JacobiWavefront => (2, 1, 6),
        Scheme::JacobiMultiGroup => (4, 2, 8),
        Scheme::JacobiDiamond => (2, 2, 4),
        Scheme::GsWavefront => (2, 2, 5),
        Scheme::GsMultiGroup => (3, 2, 5),
    };
    let r = op.radius();
    let ny = (2 * r + 2 * r * groups + 3).max(2 * r + 5);
    let mut cfg =
        RunConfig { scheme, op, size: (0, ny, 9), t, groups, iters, ranks, ..Default::default() };
    cfg.size.0 = 2 * r + ranks * cfg.halo_depth() + ranks + 1;
    cfg
}

/// Run `cfg` through a `RankSet` and assert the multi-rank result is
/// bit-identical to the registry's serial reference on the full domain
/// — the distributed counterpart of [`assert_bit_parity`].
pub fn assert_rank_parity(cfg: &RunConfig, seed: u64) {
    let (nz, ny, nx) = cfg.size;
    let f = Grid3::random(nz, ny, nx, seed);
    let u0 = Grid3::random(nz, ny, nx, seed ^ 0x5A5A);
    let mut set = RankSet::builder(cfg).rhs(f, 0.9).build().unwrap();
    let mut u = u0.clone();
    set.run(&mut u, cfg.iters).unwrap();
    let want = set.reference(&u0, cfg.iters);
    let ctx = format!(
        "{:?} x {:?} {nz}x{ny}x{nx} t={} groups={} iters={} ranks={}",
        cfg.scheme, cfg.op, cfg.t, cfg.groups, cfg.iters, cfg.ranks
    );
    assert_eq!(u.max_abs_diff(&want), 0.0, "{ctx}: multi-rank vs serial reference");
    if cfg.ranks > 1 {
        let stats = set.halo_stats();
        assert!(stats.messages > 0, "{ctx}: halos must actually move between ranks");
    }
}

/// The full `Scheme::ALL` × `OpKind::ALL` matrix at one rank count.
pub fn assert_rank_matrix(ranks: usize, seed: u64) {
    for scheme in Scheme::ALL {
        for op in OpKind::ALL {
            assert_rank_parity(&rank_parity_config(scheme, op, ranks), seed);
        }
    }
}

/// One generated tenant job for the multi-tenant suites: a valid config
/// plus the seed its grids derive from.
#[derive(Clone, Debug)]
pub struct TenantJob {
    pub cfg: RunConfig,
    pub seed: u64,
}

/// Seeded mixed tenant workload over `Scheme::ALL` × `OpKind::ALL`:
/// `count` jobs drawn by `gen`, each at a parallel width drawn from
/// `widths`, with `make` mapping (scheme, op, width) to a valid config —
/// [`parity_config`] for the single-rank service suites,
/// [`rank_parity_config`] for the distributed harness. One generator,
/// every multi-tenant suite: the stress, property and rank harnesses
/// draw from the same distribution, so a scheme × op combination cannot
/// be stressed in one suite and silently absent from another.
pub fn tenant_jobs_with(
    gen: &mut Gen,
    count: usize,
    widths: &[usize],
    make: impl Fn(Scheme, OpKind, usize) -> RunConfig,
) -> Vec<TenantJob> {
    (0..count)
        .map(|_| {
            let scheme = gen.pick(&Scheme::ALL);
            let op = gen.pick(&OpKind::ALL);
            let width = gen.pick(widths).max(1);
            TenantJob { cfg: make(scheme, op, width), seed: gen.next() }
        })
        .collect()
}

/// [`tenant_jobs_with`] over [`parity_config`] — the service suites'
/// default workload.
pub fn tenant_jobs(gen: &mut Gen, count: usize, widths: &[usize]) -> Vec<TenantJob> {
    tenant_jobs_with(gen, count, widths, parity_config)
}

/// A tenant job's grids, derived from its seed exactly as
/// [`assert_bit_parity`] derives them: `(f, u0, h2)` with
/// `f = random(seed)`, `u0 = random(seed ^ 0xA5A5)`, `h2 = 0.9`.
pub fn tenant_grids(cfg: &RunConfig, seed: u64) -> (Grid3, Grid3, f64) {
    let (nz, ny, nx) = cfg.size;
    (Grid3::random(nz, ny, nx, seed), Grid3::random(nz, ny, nx, seed ^ 0xA5A5), 0.9)
}

/// The serial per-job reference a multi-tenant execution of this job
/// must match bit-exactly — straight from the scheme registry, so no
/// worker team is spawned just to verify.
pub fn tenant_reference(cfg: &RunConfig, seed: u64) -> Grid3 {
    let (f, u0, h2) = tenant_grids(cfg, seed);
    let op = cfg.op.instantiate(cfg.size);
    runner_for(cfg.scheme, cfg.op).unwrap().reference(&op, &u0, &f, h2, cfg, cfg.iters)
}

/// A service shape that admits every generated job: `group_width`-wide
/// cache groups, enough of them for the widest team in `jobs` — and at
/// least two, so the placement model always has a real choice. Sizing
/// from the workload keeps the suites valid under any
/// `STENCILWAVE_THREADS` width list.
pub fn tenant_service_shape(jobs: &[TenantJob], group_width: usize) -> ServiceConfig {
    let widest = jobs
        .iter()
        .map(|j| runner_for(j.cfg.scheme, j.cfg.op).unwrap().team_size(&j.cfg).max(1))
        .max()
        .unwrap_or(1);
    let groups = widest.div_ceil(group_width).max(2);
    ServiceConfig { groups, group_width, ..ServiceConfig::default() }
}

/// Seed-kernel serial reference for `iters` `ConstLaplace7` updates —
/// `gs_sweeps` for the in-place family, `jacobi_steps` otherwise.
pub fn seed_reference(gs: bool, u0: &Grid3, f: &Grid3, h2: f64, iters: usize) -> Grid3 {
    if gs {
        let mut w = u0.clone();
        gs_sweeps(&mut w, iters, GsKernel::Interleaved);
        w
    } else {
        jacobi_steps(u0, f, h2, iters)
    }
}
