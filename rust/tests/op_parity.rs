//! Kernel-parity suite for the generic `StencilOp` layer, driven by the
//! shared cross-scheme harness (`tests/common`):
//!
//! * the full `Scheme::ALL` × `OpKind::ALL` matrix is **bit-identical**
//!   to its serial references (and, for [`ConstLaplace7`], to the seed
//!   `jacobi_steps`/`gs_sweeps` kernels) at every `STENCILWAVE_THREADS`
//!   width — a scheme or op variant cannot ship without this coverage;
//! * the radius-2 [`Laplace13`] op matches an independent direct-formula
//!   serial reference sweep;
//! * the Gauss-Seidel family (`GsBaseline`, `GsWavefront`,
//!   `GsMultiGroup`) shares one update ordering: all three land on the
//!   identical grid for radius 1 and 2 across thread counts, group
//!   counts and awkward extents;
//! * the multi-group block-width restriction is typed and
//!   scheme-specific: width-`R` blocks run exact through `GsMultiGroup`
//!   (lifted) and raise `BlockWidthError` for `JacobiMultiGroup`.

mod common;

use stencilwave::config::{BlockWidthError, RunConfig, Scheme};
use stencilwave::coordinator::solver::Solver;
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::op::{op_jacobi_sweep, Laplace13, OpKind};

use common::Gen;

#[test]
fn scheme_op_matrix_is_bit_exact_at_every_thread_count() {
    for (i, threads) in common::thread_counts().into_iter().enumerate() {
        common::assert_scheme_op_matrix(threads, 0x0b5e55ed + i as u64);
    }
}

#[test]
fn randomized_shapes_stay_bit_exact_across_the_matrix() {
    // property-style: grow every dimension of the harness's minimal
    // config by a random amount so odd extents, non-divisible block
    // splits and shallow/deep z pipelines all appear
    let mut g = Gen(0xD1CE);
    for case in 0..3 {
        for scheme in Scheme::ALL {
            for op in OpKind::ALL {
                let threads = g.pick(&common::thread_counts());
                let mut cfg = common::parity_config(scheme, op, threads);
                cfg.size.0 += g.range(0, 5);
                cfg.size.1 += g.range(0, 5);
                cfg.size.2 += g.range(0, 4);
                common::assert_bit_parity(&cfg, (0x7a + case as u64) ^ g.next());
            }
        }
    }
}

#[test]
fn radius2_serial_sweep_matches_direct_formula_reference() {
    // an independent reference loop (no shared code with the op)
    let (nz, ny, nx) = (9, 8, 10);
    let u = Grid3::random(nz, ny, nx, 77);
    let f = Grid3::random(nz, ny, nx, 78);
    let h2 = 0.8;
    let mut have = Grid3::zeros(nz, ny, nx);
    op_jacobi_sweep(&Laplace13, &mut have, &u, &f, h2);
    let mut want = u.clone();
    for k in 2..nz - 2 {
        for j in 2..ny - 2 {
            for i in 2..nx - 2 {
                let s1 = u.get(k, j, i - 1)
                    + u.get(k, j, i + 1)
                    + u.get(k, j - 1, i)
                    + u.get(k, j + 1, i)
                    + u.get(k - 1, j, i)
                    + u.get(k + 1, j, i);
                let s2 = u.get(k, j, i - 2)
                    + u.get(k, j, i + 2)
                    + u.get(k, j - 2, i)
                    + u.get(k, j + 2, i)
                    + u.get(k - 2, j, i)
                    + u.get(k + 2, j, i);
                want.set(k, j, i, (16.0 * s1 - s2 + 12.0 * h2 * f.get(k, j, i)) * (1.0 / 90.0));
            }
        }
    }
    assert_eq!(have.max_abs_diff(&want), 0.0);
}

/// Run `iters` GS updates of `u0` through a scheme's session.
fn gs_result(
    scheme: Scheme,
    op: OpKind,
    size: (usize, usize, usize),
    t: usize,
    groups: usize,
    iters: usize,
    u0: &Grid3,
) -> Grid3 {
    let cfg = RunConfig { scheme, op, size, t, groups, iters, ..Default::default() };
    let mut solver = Solver::builder(&cfg).build().unwrap();
    let mut u = u0.clone();
    solver.run(&mut u, iters).unwrap();
    u
}

#[test]
fn gs_schemes_share_one_update_ordering() {
    // GsWavefront and GsMultiGroup must land on the bit-identical grid
    // GsBaseline produces, for radius 1 and 2, across thread counts,
    // group counts and awkward extents (ny not divisible by groups,
    // minimum-size blocks, the single-group degenerate case)
    let mut g = Gen(0x6A55);
    for op in [OpKind::ConstLaplace7, OpKind::Laplace13] {
        let r = op.radius();
        for threads in common::thread_counts() {
            for groups in [1usize, 2, threads.max(2)] {
                let ny = 2 * r + r * groups + g.range(0, 3); // down to minimum-size blocks
                let size = (2 * r + 1 + g.range(0, 7), ny, 2 * r + 3 + g.range(0, 4));
                let iters = 2 * threads + 1; // exercises the remainder pass
                let u0 = Grid3::random(size.0, size.1, size.2, g.next());
                let width = groups.min(2);
                let base = gs_result(Scheme::GsBaseline, op, size, threads, 1, iters, &u0);
                let wf = gs_result(Scheme::GsWavefront, op, size, threads, width, iters, &u0);
                let mg = gs_result(Scheme::GsMultiGroup, op, size, threads, groups, iters, &u0);
                let ctx = format!("{op:?} {size:?} threads={threads} groups={groups}");
                assert_eq!(wf.max_abs_diff(&base), 0.0, "{ctx}: GsWavefront vs GsBaseline");
                assert_eq!(mg.max_abs_diff(&base), 0.0, "{ctx}: GsMultiGroup vs GsBaseline");
            }
        }
    }
}

#[test]
fn block_width_restriction_is_typed_and_scheme_specific() {
    // radius 1, ny = 6: four interior lines in four width-1 blocks. The
    // in-place GS scheme runs them correctly (the 2R restriction lifts
    // to R); the Jacobi scheme rejects the same decomposition with the
    // typed validate-time error.
    let size = (8, 6, 8);
    let mut gs = common::parity_config(Scheme::GsMultiGroup, OpKind::ConstLaplace7, 4);
    gs.size = size;
    gs.groups = 4;
    gs.validate().unwrap();
    common::assert_bit_parity(&gs, 0xB10C);
    let mut jc = common::parity_config(Scheme::JacobiMultiGroup, OpKind::ConstLaplace7, 4);
    jc.size = size;
    jc.groups = 4;
    let err = jc.validate().unwrap_err();
    let typed = err.downcast_ref::<BlockWidthError>().expect("typed width error");
    assert_eq!((typed.scheme, typed.required, typed.interior), (Scheme::JacobiMultiGroup, 2, 4));
    // the builder surfaces the identical typed error (no later panic)
    let built = Solver::builder(&jc).build().map(|_| ()).unwrap_err();
    assert!(built.downcast_ref::<BlockWidthError>().is_some());
    // beyond the lifted bound even GS rejects: 5 blocks, 4 interior lines
    gs.groups = 5;
    let err = gs.validate().unwrap_err();
    let typed = err.downcast_ref::<BlockWidthError>().expect("typed width error");
    assert_eq!((typed.scheme, typed.required), (Scheme::GsMultiGroup, 1));
}

#[test]
fn op_mix_on_one_session_pool_stays_exact() {
    // chain sessions of different ops through one pool: scratch sized
    // for the radius-2 op must not leak into the radius-1 runs
    let size = (12, 16, 11);
    let f = Grid3::random(size.0, size.1, size.2, 5);
    let mut pool = None;
    for (i, (scheme, op)) in [
        (Scheme::JacobiWavefront, OpKind::Laplace13),
        (Scheme::GsMultiGroup, OpKind::ConstLaplace7),
        (Scheme::JacobiWavefront, OpKind::VarCoeff7),
        (Scheme::GsMultiGroup, OpKind::Laplace13),
        (Scheme::JacobiWavefront, OpKind::ConstLaplace7),
    ]
    .into_iter()
    .enumerate()
    {
        let c = RunConfig { scheme, op, size, t: 4, groups: 2, iters: 8, ..Default::default() };
        let mut b = Solver::builder(&c).rhs(f.clone(), 1.0);
        if let Some(p) = pool.take() {
            b = b.pool(p);
        }
        let mut solver = b.build().unwrap();
        let u0 = Grid3::random(size.0, size.1, size.2, 40 + i as u64);
        let mut u = u0.clone();
        solver.run(&mut u, c.iters).unwrap();
        let want = solver.reference(&u0, c.iters);
        assert_eq!(u.max_abs_diff(&want), 0.0, "step {i} {scheme:?} x {op:?}");
        pool = Some(solver.into_pool());
    }
}
