//! Kernel-parity suite for the generic `StencilOp` layer (the tentpole's
//! acceptance tests):
//!
//! * the generic [`ConstLaplace7`] path is **bit-identical** to the seed
//!   `jacobi_sweep`/`gs_sweep` kernels across all five schemes and a
//!   spread of grid shapes (property-style, seeded random cases);
//! * the radius-2 [`Laplace13`] op matches an independent direct-formula
//!   serial reference sweep, and runs exact through every scheme;
//! * the variable-coefficient [`VarCoeff7`] op runs exact through every
//!   scheme.

use stencilwave::config::{RunConfig, Scheme};
use stencilwave::coordinator::solver::Solver;
use stencilwave::stencil::gauss_seidel::gs_sweeps;
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::jacobi::jacobi_steps;
use stencilwave::stencil::op::{op_jacobi_sweep, Laplace13, OpKind};

/// Deterministic pseudo-random case generator (xorshift).
struct Gen(u64);

impl Gen {
    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }
    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next() as usize) % (hi - lo + 1)
    }
}

fn cfg(scheme: Scheme, op: OpKind, size: (usize, usize, usize)) -> RunConfig {
    RunConfig { scheme, op, size, t: 4, groups: 2, iters: 8, ..Default::default() }
}

/// The seed (pre-`StencilOp`) result of `iters` updates for a scheme.
fn seed_result(scheme: Scheme, u0: &Grid3, f: &Grid3, h2: f64, c: &RunConfig) -> Grid3 {
    if scheme.is_gs() {
        let mut r = u0.clone();
        gs_sweeps(&mut r, c.iters, c.gs_kernel());
        r
    } else {
        jacobi_steps(u0, f, h2, c.iters)
    }
}

#[test]
fn const7_generic_path_is_bit_identical_to_seed_kernels_across_schemes() {
    let mut g = Gen(0x0b5e55ed);
    for case in 0..6 {
        // shapes wide enough for every scheme's width requirements
        let size = (g.range(10, 16), g.range(12, 18), g.range(9, 14));
        let (nz, ny, nx) = size;
        let f = Grid3::random(nz, ny, nx, g.next());
        let u0 = Grid3::random(nz, ny, nx, g.next());
        let h2 = 0.5 + g.range(0, 2) as f64 / 2.0;
        for scheme in Scheme::ALL {
            let c = cfg(scheme, OpKind::ConstLaplace7, size);
            let mut solver = Solver::builder(&c).rhs(f.clone(), h2).build().unwrap();
            let mut u = u0.clone();
            solver.run(&mut u, c.iters).unwrap();
            let want = seed_result(scheme, &u0, &f, h2, &c);
            assert_eq!(
                u.max_abs_diff(&want),
                0.0,
                "case {case} {scheme:?} {nz}x{ny}x{nx}: generic ConstLaplace7 \
                 must be bit-identical to the seed kernels"
            );
        }
    }
}

#[test]
fn radius2_serial_sweep_matches_direct_formula_reference() {
    // an independent reference loop (no shared code with the op)
    let (nz, ny, nx) = (9, 8, 10);
    let u = Grid3::random(nz, ny, nx, 77);
    let f = Grid3::random(nz, ny, nx, 78);
    let h2 = 0.8;
    let mut have = Grid3::zeros(nz, ny, nx);
    op_jacobi_sweep(&Laplace13, &mut have, &u, &f, h2);
    let mut want = u.clone();
    for k in 2..nz - 2 {
        for j in 2..ny - 2 {
            for i in 2..nx - 2 {
                let s1 = u.get(k, j, i - 1)
                    + u.get(k, j, i + 1)
                    + u.get(k, j - 1, i)
                    + u.get(k, j + 1, i)
                    + u.get(k - 1, j, i)
                    + u.get(k + 1, j, i);
                let s2 = u.get(k, j, i - 2)
                    + u.get(k, j, i + 2)
                    + u.get(k, j - 2, i)
                    + u.get(k, j + 2, i)
                    + u.get(k - 2, j, i)
                    + u.get(k + 2, j, i);
                want.set(k, j, i, (16.0 * s1 - s2 + 12.0 * h2 * f.get(k, j, i)) * (1.0 / 90.0));
            }
        }
    }
    assert_eq!(have.max_abs_diff(&want), 0.0);
}

#[test]
fn radius2_runs_exact_through_every_scheme() {
    let mut g = Gen(0x13);
    for case in 0..4 {
        let size = (g.range(11, 15), g.range(14, 20), g.range(10, 13));
        let (nz, ny, nx) = size;
        let f = Grid3::random(nz, ny, nx, g.next());
        let u0 = Grid3::random(nz, ny, nx, g.next());
        for scheme in Scheme::ALL {
            let c = cfg(scheme, OpKind::Laplace13, size);
            let mut solver = Solver::builder(&c).rhs(f.clone(), 0.9).build().unwrap();
            let mut u = u0.clone();
            solver.run(&mut u, c.iters).unwrap();
            // the session's reference is the generic serial sweep of the
            // same op instance — exactness across the parallel schedules
            // is the property under test
            let want = solver.reference(&u0, c.iters);
            assert_eq!(u.max_abs_diff(&want), 0.0, "case {case} {scheme:?} {nz}x{ny}x{nx}");
        }
    }
}

#[test]
fn varcoeff_runs_exact_through_every_scheme() {
    let mut g = Gen(0x7a);
    for case in 0..4 {
        let size = (g.range(9, 13), g.range(12, 16), g.range(8, 12));
        let (nz, ny, nx) = size;
        let f = Grid3::random(nz, ny, nx, g.next());
        let u0 = Grid3::random(nz, ny, nx, g.next());
        for scheme in Scheme::ALL {
            let c = cfg(scheme, OpKind::VarCoeff7, size);
            let mut solver = Solver::builder(&c).rhs(f.clone(), 1.1).build().unwrap();
            let mut u = u0.clone();
            solver.run(&mut u, c.iters).unwrap();
            let want = solver.reference(&u0, c.iters);
            assert_eq!(u.max_abs_diff(&want), 0.0, "case {case} {scheme:?} {nz}x{ny}x{nx}");
        }
    }
}

#[test]
fn op_mix_on_one_session_pool_stays_exact() {
    // chain sessions of different ops through one pool: scratch sized
    // for the radius-2 op must not leak into the radius-1 runs
    let size = (12, 16, 11);
    let f = Grid3::random(size.0, size.1, size.2, 5);
    let mut pool = None;
    for (i, op) in [OpKind::Laplace13, OpKind::ConstLaplace7, OpKind::VarCoeff7, OpKind::Laplace13]
        .into_iter()
        .enumerate()
    {
        let c = cfg(Scheme::JacobiWavefront, op, size);
        let mut b = Solver::builder(&c).rhs(f.clone(), 1.0);
        if let Some(p) = pool.take() {
            b = b.pool(p);
        }
        let mut solver = b.build().unwrap();
        let u0 = Grid3::random(size.0, size.1, size.2, 40 + i as u64);
        let mut u = u0.clone();
        solver.run(&mut u, c.iters).unwrap();
        let want = solver.reference(&u0, c.iters);
        assert_eq!(u.max_abs_diff(&want), 0.0, "step {i} {op:?}");
        pool = Some(solver.into_pool());
    }
}
