//! Cross-layer integration: PJRT-executed Pallas artifacts vs rust engine.
//!
//! Requires building with `--features xla` (the whole file is compiled
//! out otherwise) and `make artifacts`; every test self-skips when the
//! catalog is absent so `cargo test` stays green on a fresh checkout,
//! while `make test` (which builds artifacts first) exercises the full
//! path.
#![cfg(feature = "xla")]

use stencilwave::runtime::{engine, Manifest, Runtime};
use stencilwave::stencil::gauss_seidel::{gs_sweeps, GsKernel};
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::jacobi::jacobi_steps;
use stencilwave::stencil::residual::poisson_residual_norm;

fn runtime() -> Option<Runtime> {
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(Runtime::load(&dir).expect("runtime must load when artifacts exist"))
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

#[test]
fn jacobi_step_artifact_matches_rust_engine() {
    let Some(mut rt) = runtime() else { return };
    let u = Grid3::random(16, 16, 16, 1);
    let f = Grid3::random(16, 16, 16, 2);
    let pallas = rt.run_grid("jacobi_step_n16", &[&u, &f]).unwrap();
    let mine = jacobi_steps(&u, &f, 1.0, 1);
    assert!(mine.max_abs_diff(&pallas) < 1e-12);
}

#[test]
fn multi_iteration_sweep_artifact_matches() {
    let Some(mut rt) = runtime() else { return };
    let info = rt.manifest().get("jacobi_sweep_n16_it4").unwrap().clone();
    let iters = info.param_usize("iters").unwrap();
    let u = Grid3::random(16, 16, 16, 3);
    let f = Grid3::random(16, 16, 16, 4);
    let pallas = rt.run_grid("jacobi_sweep_n16_it4", &[&u, &f]).unwrap();
    let mine = jacobi_steps(&u, &f, 1.0, iters);
    assert!(mine.max_abs_diff(&pallas) < 1e-11);
}

#[test]
fn wavefront_artifact_equals_fused_updates() {
    let Some(mut rt) = runtime() else { return };
    let info = rt.manifest().get("jacobi_wavefront_n16_t2").unwrap().clone();
    let t = info.param_usize("wavefront_t").unwrap();
    let u = Grid3::random(16, 16, 16, 5);
    let f = Grid3::random(16, 16, 16, 6);
    let pallas = rt.run_grid("jacobi_wavefront_n16_t2", &[&u, &f]).unwrap();
    // the fused Pallas wavefront must equal t plain steps — same invariant
    // the rust wavefront engine upholds
    let mine = jacobi_steps(&u, &f, 1.0, t);
    assert!(mine.max_abs_diff(&pallas) < 1e-11);
}

#[test]
fn gs_sweep_artifact_matches_lexicographic_order() {
    let Some(mut rt) = runtime() else { return };
    let u = Grid3::random(16, 16, 16, 7);
    let pallas = rt.run_grid("gs_sweep_n16", &[&u]).unwrap();
    let mut mine = u.clone();
    gs_sweeps(&mut mine, 1, GsKernel::Interleaved);
    assert!(mine.max_abs_diff(&pallas) < 1e-12, "GS update order must agree across layers");
}

#[test]
fn residual_artifact_matches_rust_norm() {
    let Some(mut rt) = runtime() else { return };
    let u = Grid3::random(16, 16, 16, 8);
    let f = Grid3::random(16, 16, 16, 9);
    let pallas = rt.run_scalar("residual_n16", &[&u, &f]).unwrap();
    let mine = poisson_residual_norm(&u, &f, 1.0);
    assert!((pallas - mine).abs() < 1e-10 * mine.max(1.0), "{pallas} vs {mine}");
}

#[test]
fn smooth_and_residual_artifact_returns_both() {
    let Some(mut rt) = runtime() else { return };
    let u = Grid3::random(16, 16, 16, 10);
    let f = Grid3::random(16, 16, 16, 11);
    let (out, rn) = rt.run_grid_scalar("jacobi_smooth_residual_n16_it4", &[&u, &f]).unwrap();
    let mine = jacobi_steps(&u, &f, 1.0, 4);
    assert!(mine.max_abs_diff(&out) < 1e-11);
    let my_rn = poisson_residual_norm(&mine, &f, 1.0);
    assert!((rn - my_rn).abs() < 1e-9 * my_rn.max(1.0));
}

#[test]
fn validate_helper_passes_whole_catalog() {
    let Some(mut rt) = runtime() else { return };
    let names: Vec<String> = rt
        .manifest()
        .artifacts
        .iter()
        .filter(|a| matches!(a.scheme(), Some("jacobi") | Some("gauss_seidel")))
        .filter(|a| a.name.contains("n16")) // keep the test fast
        .map(|a| a.name.clone())
        .collect();
    assert!(names.len() >= 4);
    for name in names {
        let v = engine::validate(&mut rt, &name).unwrap();
        assert!(v.passed(), "{}: {} > tol {}", v.artifact, v.max_abs_diff, v.tolerance);
    }
}

#[test]
fn shape_mismatch_is_rejected() {
    let Some(mut rt) = runtime() else { return };
    let wrong = Grid3::random(8, 8, 8, 1);
    let f = Grid3::random(8, 8, 8, 2);
    assert!(rt.run_grid("jacobi_step_n16", &[&wrong, &f]).is_err());
    assert!(rt.run_grid("no_such_artifact", &[&wrong]).is_err());
}
