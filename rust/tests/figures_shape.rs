//! Shape assertions for every regenerated table/figure: the qualitative
//! claims of the paper's evaluation section must hold in the model
//! (DESIGN.md §5 "expected shapes").

use stencilwave::figures::{self, WavefrontPoint};

fn at_200(points: &[WavefrontPoint], machine: &str) -> WavefrontPoint {
    points
        .iter()
        .find(|p| p.machine == machine && p.n == 200)
        .unwrap_or_else(|| panic!("missing {machine}@200"))
        .clone()
}

#[test]
fn tab1_shape() {
    let rows = figures::tab1();
    assert_eq!(rows.len(), 5);
    // Harpertown is the bandwidth-starved machine; EP/Westmere the fat ones.
    let by = |n: &str| rows.iter().find(|r| r.machine == n).unwrap().stream_socket_nt_gbs;
    assert!(by("Core 2") < by("Nehalem EX"));
    assert!(by("Nehalem EX") < by("Nehalem EP"), "EX has half its memory cards");
    assert!(by("Nehalem EP") < by("Westmere"));
}

#[test]
fn fig3a_shape() {
    let rows = figures::fig3a();
    for r in &rows {
        assert!(r.opt_cache >= r.c_cache, "{}: optimized must win in cache", r.machine);
        assert!(r.opt_cache >= r.opt_memory, "{}: cache >= memory", r.machine);
    }
    // Largest in-cache-to-memory drop on Core 2 (vs EP/Westmere/Istanbul).
    let drop = |n: &str| {
        let r = rows.iter().find(|r| r.machine == n).unwrap();
        r.opt_cache / r.opt_memory
    };
    assert!(drop("Core 2") > drop("Nehalem EP"));
    assert!(drop("Core 2") > drop("Westmere"));
    // EP/Westmere: "the serial Jacobi is not primarily bandwidth limited"
    assert!(drop("Nehalem EP") < 1.6, "{}", drop("Nehalem EP"));
    assert!(drop("Westmere") < 1.6, "{}", drop("Westmere"));
    // Istanbul: optimizations show little effect in cache
    let ist = rows.iter().find(|r| r.machine == "Istanbul").unwrap();
    let ep = rows.iter().find(|r| r.machine == "Nehalem EP").unwrap();
    assert!(ist.opt_cache / ist.c_cache < ep.opt_cache / ep.c_cache);
}

#[test]
fn fig3b_shape() {
    for r in figures::fig3b() {
        // threaded memory performance must respect the Eq. (1) ceiling
        assert!(
            r.opt_memory <= r.eq1_limit * 1.01,
            "{}: {} > limit {}",
            r.machine,
            r.opt_memory,
            r.eq1_limit
        );
        // and the in-cache socket run must beat the memory run
        assert!(r.opt_cache >= r.opt_memory * 0.99, "{}", r.machine);
    }
}

#[test]
fn fig4a_shape() {
    let rows = figures::fig4a();
    let jacobi = figures::fig3a();
    for (r, j) in rows.iter().zip(&jacobi) {
        // the dependency interleaving is the big serial GS win
        assert!(r.opt_cache > 1.3 * r.c_cache, "{}: interleaving gain missing", r.machine);
        // "there is no substantial drop between in-cache and memory
        // performance" for the recursion-bound C Gauss-Seidel — its drop
        // must be clearly smaller than the C Jacobi drop on each machine
        let gs_drop = r.c_cache / r.c_memory;
        let jac_drop = j.c_cache / j.c_memory;
        // (0.9 rather than a hard margin: on Istanbul both drops are small
        // because cache transfers dominate everything — paper Fig. 3/4)
        assert!(
            gs_drop < 0.9 * jac_drop,
            "{}: GS drop {gs_drop:.2} !< Jacobi drop {jac_drop:.2}",
            r.machine
        );
    }
}

#[test]
fn fig4b_shape() {
    let rows = figures::fig4b();
    for r in &rows {
        assert!(r.opt_memory <= r.eq1_limit * 1.01, "{}", r.machine);
    }
    // Westmere benefits from its two extra cores over Nehalem EP.
    let wm = rows.iter().find(|r| r.machine == "Westmere").unwrap();
    let ep = rows.iter().find(|r| r.machine == "Nehalem EP").unwrap();
    assert!(wm.opt_cache > ep.opt_cache);
}

#[test]
fn fig8_shape() {
    let pts = figures::fig8();
    // Paper prose: Core2 ≈ 2×, EP +25..50%, EX ≈ 4× (size-independent),
    // Istanbul only comparable to EP despite the bigger gap.
    let core2 = at_200(&pts, "Core 2");
    assert!(core2.speedup > 1.6 && core2.speedup < 2.6, "{}", core2.speedup);
    let ep = at_200(&pts, "Nehalem EP");
    assert!(ep.speedup > 1.1 && ep.speedup < 1.7, "{}", ep.speedup);
    let ex = at_200(&pts, "Nehalem EX");
    assert!(ex.speedup > 3.0 && ex.speedup < 5.0, "{}", ex.speedup);
    let ist = at_200(&pts, "Istanbul");
    assert!(ist.speedup < ep.speedup * 1.4, "Istanbul must disappoint: {}", ist.speedup);
    // EX speedup roughly size-independent across the sweep
    let ex_all: Vec<f64> =
        pts.iter().filter(|p| p.machine == "Nehalem EX").map(|p| p.speedup).collect();
    let (lo, hi) = ex_all.iter().fold((f64::MAX, 0.0f64), |(l, h), &s| (l.min(s), h.max(s)));
    assert!(hi / lo < 1.4, "EX spread too wide: {lo}..{hi}");
    // blocking factors follow the cache groups
    assert_eq!(core2.blocking_factor, 2);
    assert_eq!(ex.blocking_factor, 8);
    assert_eq!(at_200(&pts, "Westmere").blocking_factor, 6);
}

#[test]
fn fig9_shape() {
    let pts = figures::fig9();
    let core2 = at_200(&pts, "Core 2");
    assert!(core2.speedup > 1.5 && core2.speedup < 2.5, "{}", core2.speedup);
    let ep = at_200(&pts, "Nehalem EP");
    assert!(ep.speedup > 1.1 && ep.speedup < 1.8, "{}", ep.speedup);
    let wm = at_200(&pts, "Westmere");
    assert!(wm.speedup > 1.3, "Westmere profits from deeper blocking: {}", wm.speedup);
    let ex = at_200(&pts, "Nehalem EX");
    assert!(ex.speedup > 2.8 && ex.speedup < 4.8, "EX ≈ 3.8×: {}", ex.speedup);
    // EX best overall performance despite the lowest Nehalem bandwidth
    let best = pts.iter().filter(|p| p.n == 200).map(|p| p.wavefront_mlups).fold(0.0, f64::max);
    assert_eq!(best, ex.wavefront_mlups, "EX must lead Fig. 9");
}

#[test]
fn fig10_shape() {
    let pts = figures::fig10();
    let ep = at_200(&pts, "Nehalem EP");
    let wm = at_200(&pts, "Westmere");
    let ex = at_200(&pts, "Nehalem EX");
    // EP and Westmere ≈ 2.5× their threaded baselines
    assert!(ep.speedup > 2.0 && ep.speedup < 3.2, "{}", ep.speedup);
    assert!(wm.speedup > 1.8 && wm.speedup < 3.2, "{}", wm.speedup);
    // EX up to 5× overall
    assert!(ex.speedup > 3.5 && ex.speedup < 5.5, "{}", ex.speedup);
    // arithmetic plateau: the three reach comparable absolute performance
    let perf = [ep.wavefront_mlups, wm.wavefront_mlups, ex.wavefront_mlups];
    let hi = perf.iter().fold(0.0f64, |a, &b| a.max(b));
    let lo = perf.iter().fold(f64::MAX, |a, &b| a.min(b));
    assert!(hi / lo < 1.6, "plateau spread: {perf:?}");
    // SMT gain on EX smaller than on EP (EX already arithmetic-limited)
    let no_smt = figures::fig9();
    let gain = |m: &str| at_200(&pts, m).wavefront_mlups / at_200(&no_smt, m).wavefront_mlups;
    assert!(gain("Nehalem EX") < gain("Nehalem EP"), "EX gain must be smaller");
}

#[test]
fn barrier_table_shape() {
    for r in figures::barrier_table() {
        assert!(r.pthread_cycles > 4.0 * r.spin_cycles, "pthread unusable @{}", r.threads);
        if r.threads >= 4 {
            assert!(r.tree_cycles_smt < r.spin_cycles_smt, "tree wins under SMT @{}", r.threads);
        }
    }
}
