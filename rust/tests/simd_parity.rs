//! SIMD-vs-scalar bit-parity, driven through forced ISA dispatch.
//!
//! `Isa::force` pins the *process-global* dispatch decision, so every
//! forced-ISA comparison lives in this one integration crate — and in
//! ONE `#[test]` fn, because `cargo test` runs a crate's tests on
//! in-process threads that would otherwise interleave their forces.
//! (`src/stencil/simd.rs` unit tests stay race-free by only using the
//! explicit `_with(isa, ...)` entry points.)
//!
//! Coverage: the shared `tests/common` scheme × op matrix re-run under
//! each forced ISA, a direct scalar-vs-AVX grid comparison for every
//! `Scheme::ALL` × `OpKind::ALL` × `nt_stores` cell, and an
//! nt-on-vs-off comparison on the Jacobi family (the schemes whose
//! executed store instructions the flag actually switches).

mod common;

use stencilwave::config::Scheme;
use stencilwave::coordinator::solver::Solver;
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::op::OpKind;
use stencilwave::stencil::simd::Isa;

/// Run `cfg` through a fresh `Solver` session under the *currently
/// forced* ISA and return the result grid.
fn run(cfg: &stencilwave::config::RunConfig, seed: u64) -> Grid3 {
    let (nz, ny, nx) = cfg.size;
    let f = Grid3::random(nz, ny, nx, seed);
    let mut u = Grid3::random(nz, ny, nx, seed ^ 0xA5A5);
    let mut solver = Solver::builder(cfg).rhs(f, 0.9).build().unwrap();
    solver.run(&mut u, cfg.iters).unwrap();
    u
}

#[test]
fn forced_isa_and_store_mode_runs_are_bit_identical() {
    let seed = 0x51D0;
    let threads = *common::thread_counts().last().unwrap();

    // leg 1: the shared parity harness (parallel vs serial reference,
    // seed-kernel parity for laplace7) stays green under each forced
    // ISA. A forced Avx clamps to Scalar on hardware without AVX, so
    // this is safe — and still meaningful — on any runner.
    for isa in [Isa::Scalar, Isa::Avx] {
        Isa::force(Some(isa));
        common::assert_scheme_op_matrix(threads, seed);
    }

    // leg 2: scalar and (clamped) AVX sessions land on bit-identical
    // grids for every scheme × op × nt_stores cell — the lane kernels
    // keep the scalar association, remainder lanes included.
    for scheme in Scheme::ALL {
        for op in OpKind::ALL {
            for nt_stores in [false, true] {
                let mut cfg = common::parity_config(scheme, op, threads);
                cfg.nt_stores = nt_stores;
                Isa::force(Some(Isa::Scalar));
                let scalar = run(&cfg, seed);
                Isa::force(Some(Isa::Avx));
                let vector = run(&cfg, seed);
                let ctx = format!("{scheme:?} x {op:?} nt_stores={nt_stores}");
                assert_eq!(vector.max_abs_diff(&scalar), 0.0, "{ctx}: AVX vs scalar");
            }
        }
    }

    // leg 3: streaming stores change the executed store instructions
    // and the modeled traffic, never the values — nt on/off agree
    // bit-exactly on the schemes where the flag is live.
    Isa::force(Some(Isa::Avx));
    for scheme in [Scheme::JacobiBaseline, Scheme::JacobiWavefront, Scheme::JacobiMultiGroup] {
        let mut on = common::parity_config(scheme, OpKind::ConstLaplace7, threads);
        on.nt_stores = true;
        let mut off = on.clone();
        off.nt_stores = false;
        let diff = run(&on, seed).max_abs_diff(&run(&off, seed));
        assert_eq!(diff, 0.0, "{scheme:?}: nt_stores on vs off");
    }

    // restore lazy probing for anything that runs after this test
    Isa::force(None);
}
