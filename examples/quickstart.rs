//! Quickstart: the wavefront scheme in five minutes.
//!
//! 1. Build a Poisson problem on a 64³ grid.
//! 2. Smooth it with the plain threaded Jacobi baseline.
//! 3. Smooth it with wavefront temporal blocking (t = 4) — same numerics,
//!    a fraction of the memory traffic.
//! 4. Do the same for Gauss-Seidel via the pipeline-parallel wavefront.
//! 5. Ask the simulator what this configuration would do on the paper's
//!    Nehalem EX.
//!
//! Run with: `cargo run --release --example quickstart`

use stencilwave::coordinator::wavefront::{wavefront_jacobi_iters, WavefrontConfig};
use stencilwave::coordinator::wavefront_gs::{wavefront_gs_iters, GsWavefrontConfig};
use stencilwave::metrics::{mlups, timed};
use stencilwave::simulator::ecm::Kernel;
use stencilwave::simulator::machine::MachineSpec;
use stencilwave::simulator::perfmodel::{wavefront_prediction, WavefrontParams};
use stencilwave::stencil::gauss_seidel::GsKernel;
use stencilwave::stencil::grid::Grid3;
use stencilwave::stencil::jacobi::jacobi_steps;
use stencilwave::stencil::residual::poisson_residual_norm;

fn main() -> stencilwave::Result<()> {
    const N: usize = 64;
    const ITERS: usize = 8;
    const T: usize = 4;
    let h2 = 1.0;

    println!("== stencilwave quickstart: {N}^3 Poisson problem, {ITERS} updates ==\n");
    let f = Grid3::from_fn(N, N, N, |k, j, i| {
        let (x, y, z) = (i as f64 / N as f64, j as f64 / N as f64, k as f64 / N as f64);
        (x * y * z).sin() + 1.0
    });
    let u0 = Grid3::random(N, N, N, 42);
    let updates = (u0.interior_len() * ITERS) as u64;

    // 1 — plain Jacobi baseline
    let (baseline, dt) = timed(|| jacobi_steps(&u0, &f, h2, ITERS));
    println!("jacobi baseline   : {:8.1} MLUP/s", mlups(updates, dt));

    // 2 — wavefront temporal blocking, bit-identical result
    let mut u = u0.clone();
    let cfg = WavefrontConfig { threads: T, ..Default::default() };
    let (res, dt) = timed(|| wavefront_jacobi_iters(&mut u, &f, h2, &cfg, ITERS));
    res?;
    println!(
        "jacobi wavefront  : {:8.1} MLUP/s   max|diff| vs baseline = {:.1e}",
        mlups(updates, dt),
        u.max_abs_diff(&baseline)
    );
    assert_eq!(u.max_abs_diff(&baseline), 0.0, "temporal blocking must not change numerics");
    println!(
        "residual after {ITERS} Jacobi updates: {:.6e}",
        poisson_residual_norm(&u, &f, h2)
    );

    // 3 — Gauss-Seidel wavefront (Laplace problem, in place)
    let mut g = u0.clone();
    let gs_cfg = GsWavefrontConfig { sweeps: T, threads_per_group: 2, kernel: GsKernel::Interleaved };
    let (res, dt) = timed(|| wavefront_gs_iters(&mut g, &gs_cfg, ITERS));
    res?;
    println!("\ngs wavefront      : {:8.1} MLUP/s", mlups(updates, dt));

    // 4 — what would the paper's testbed do?
    println!("\npredictions for this configuration (200^3, t = max blocking factor):");
    for m in MachineSpec::testbed() {
        let p = WavefrontParams::standard(&m, Kernel::JacobiOpt, false);
        let pred = wavefront_prediction(&m, &p, (200, 200, 200));
        println!(
            "  {:<12} t={}: {:6.0} MLUP/s (compute {:.0} | cache {:.0} | memory {:.0})",
            m.name, p.t, pred.mlups, pred.compute_mlups, pred.olc_mlups, pred.mem_mlups
        );
    }
    Ok(())
}
