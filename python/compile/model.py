"""L2 — JAX compute graphs for the iterative smoothers.

These functions compose the L1 Pallas kernels into the multi-iteration
smoothers the paper benchmarks, plus the residual diagnostics the
end-to-end example needs. Everything here is build-time only: ``aot.py``
lowers each entry point once to HLO text, and the rust runtime executes the
artifacts — Python is never on the request path.

Iteration counts use ``lax.scan`` so the lowered HLO stays O(1) in the
number of iterations (a while loop over a fixed body) instead of unrolling
— see DESIGN.md §Perf (L2).

All graphs are double precision (the paper's Eq. 1 assumes 8-byte values);
``aot.py`` enables x64 before tracing.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from .kernels import gauss_seidel as gs_kernels
from .kernels import jacobi as jacobi_kernels
from .kernels import ref
from .kernels import wavefront as wavefront_kernels


def jacobi_smoother(u: jnp.ndarray, f: jnp.ndarray, h2: float, n_iter: int) -> jnp.ndarray:
    """``n_iter`` Jacobi updates via the Pallas plane kernel (baseline path).

    This is the paper's *non-temporally-blocked* Jacobi: every iteration
    streams the whole grid, so DRAM traffic is ``n_iter · 16 B`` per site.
    """

    def body(carry, _):
        return jacobi_kernels.jacobi_step(carry, f, h2), None

    out, _ = lax.scan(body, u, None, length=n_iter)
    return out


def jacobi_wavefront_smoother(
    u: jnp.ndarray, f: jnp.ndarray, h2: float, t: int, n_outer: int
) -> jnp.ndarray:
    """``n_outer`` fused wavefront passes of temporal depth ``t``.

    Performs ``n_outer · t`` Jacobi updates while touching HBM only
    ``n_outer`` times per plane — the TPU rendering of the paper's
    thread-group wavefront (Fig. 6). Numerically identical to
    ``jacobi_smoother(u, f, h2, t * n_outer)``.
    """

    def body(carry, _):
        return wavefront_kernels.wavefront_steps(carry, f, h2, t), None

    out, _ = lax.scan(body, u, None, length=n_outer)
    return out


def gs_smoother(u: jnp.ndarray, n_iter: int) -> jnp.ndarray:
    """``n_iter`` lexicographic Gauss-Seidel sweeps (Laplace problem)."""
    return gs_kernels.gs_sweeps(u, n_iter)


def residual_norm(u: jnp.ndarray, f: jnp.ndarray, h2: float) -> jnp.ndarray:
    """L2 norm of the Poisson residual (pure-jnp diagnostic graph)."""
    return ref.l2_norm(ref.residual(u, f, h2))


def jacobi_smooth_and_residual(
    u: jnp.ndarray, f: jnp.ndarray, h2: float, n_iter: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Smoother step fused with its convergence diagnostic.

    One artifact, one PJRT dispatch per outer solver iteration — the shape
    the rust Poisson driver (examples/poisson_solver.rs) wants.
    """
    out = jacobi_smoother(u, f, h2, n_iter)
    return out, residual_norm(out, f, h2)


def gs_smooth_and_residual(
    u: jnp.ndarray, n_iter: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """GS sweeps fused with the Laplace residual norm (f = 0)."""
    out = gs_smoother(u, n_iter)
    zero = jnp.zeros_like(out)
    return out, residual_norm(out, zero, 1.0)
