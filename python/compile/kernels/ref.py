"""Pure-reference oracles for the stencil kernels.

These are the *correctness ground truth* for every Pallas kernel in this
package and (via the AOT artifacts) for the rust execution engine as well.
Two styles are provided on purpose:

* ``jnp``-vectorized references (`jacobi_step`, `jacobi_steps`,
  `residual`, `l2_norm`, `gauss_seidel_sweep`) — fast enough to run inside
  lowered graphs and to serve as the in-graph baseline the paper calls the
  "C implementation".
* ``numpy`` loop references (`gauss_seidel_sweep_np`, `jacobi_step_np`) —
  direct transliterations of the paper's C listings (Sec. 3), used only in
  pytest. Being triple-loop scalar code they are slow but unarguably
  correct, including the lexicographic update order of Gauss-Seidel.

Conventions
-----------
Grids are ``(nz, ny, nx)`` double-precision arrays (the paper uses double
precision throughout; Eq. 1 assumes 8-byte values). The outermost index is
``z`` (planes), then ``y`` (lines), then ``x`` (contiguous). Dirichlet
boundaries: the faces of the box are never updated.

The Jacobi smoother targets a Poisson problem  ``-Δu = f``:

    u'[k,j,i] = (1/6) * ( u[k±1,j,i] + u[k,j±1,i] + u[k,j,i±1] + h²·f[k,j,i] )

The Gauss-Seidel smoother targets a Laplace problem (``f = 0``) with the
in-place lexicographic update of the paper:

    u[k,j,i] = (1/6) * ( u[k-1,j,i] + u[k,j-1,i] + u[k,j,i-1]      (new)
                       + u[k+1,j,i] + u[k,j+1,i] + u[k,j,i+1] )    (old)
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from jax import lax

#: Central weight of the 7-point stencil for the unit Laplacian.
ONE_SIXTH = 1.0 / 6.0


def interior_mask(shape: tuple[int, int, int]) -> jnp.ndarray:
    """Boolean mask that is True on interior points, False on the boundary."""
    nz, ny, nx = shape
    z = jnp.arange(nz)[:, None, None]
    y = jnp.arange(ny)[None, :, None]
    x = jnp.arange(nx)[None, None, :]
    return (
        (z > 0) & (z < nz - 1) & (y > 0) & (y < ny - 1) & (x > 0) & (x < nx - 1)
    )


def neighbor_sum(u: jnp.ndarray) -> jnp.ndarray:
    """Sum of the six axis neighbors, valid on interior points only.

    Uses rolls; values produced on boundary points are garbage and must be
    masked by the caller. Rolls (instead of padded slicing) keep the shapes
    static, which matters for AOT lowering.
    """
    return (
        jnp.roll(u, 1, axis=0)
        + jnp.roll(u, -1, axis=0)
        + jnp.roll(u, 1, axis=1)
        + jnp.roll(u, -1, axis=1)
        + jnp.roll(u, 1, axis=2)
        + jnp.roll(u, -1, axis=2)
    )


def jacobi_step(u: jnp.ndarray, f: jnp.ndarray, h2: float) -> jnp.ndarray:
    """One out-of-place Jacobi update on the interior; boundary copied."""
    upd = ONE_SIXTH * (neighbor_sum(u) + h2 * f)
    return jnp.where(interior_mask(u.shape), upd, u)


def jacobi_steps(u: jnp.ndarray, f: jnp.ndarray, h2: float, n: int) -> jnp.ndarray:
    """``n`` consecutive Jacobi updates (the temporal-blocking ground truth)."""

    def body(carry, _):
        return jacobi_step(carry, f, h2), None

    out, _ = lax.scan(body, u, None, length=n)
    return out


def residual(u: jnp.ndarray, f: jnp.ndarray, h2: float) -> jnp.ndarray:
    """Pointwise residual  r = h²·f + Δu  (zero on the boundary)."""
    r = neighbor_sum(u) - 6.0 * u + h2 * f
    return jnp.where(interior_mask(u.shape), r, 0.0)


def l2_norm(r: jnp.ndarray) -> jnp.ndarray:
    """Euclidean norm of a residual field."""
    return jnp.sqrt(jnp.sum(r * r))


def gauss_seidel_plane(
    u_prev_new: jnp.ndarray, u_center: jnp.ndarray, u_next_old: jnp.ndarray
) -> jnp.ndarray:
    """Reference lexicographic GS update of a single interior plane.

    ``u_prev_new`` is plane ``k-1`` *after* its update this sweep,
    ``u_center`` plane ``k`` before, ``u_next_old`` plane ``k+1`` before.
    Implemented with a scan over lines (y) and a first-order linear
    recurrence along x — mathematically identical to the paper's triple
    loop; boundary rows/columns untouched.
    """
    ny, nx = u_center.shape
    b = ONE_SIXTH

    def line_update(prev_new_line, j):
        center = u_center[j]
        known = (
            u_prev_new[j]      # new k-1 plane, same line
            + u_next_old[j]    # old k+1 plane
            + prev_new_line    # new j-1 line of this plane
            + u_center[j + 1]  # old j+1 line
        )
        # x recursion on the interior: v[i] = b * (v[i-1] + known[i] + old
        # x+1 neighbor). First-order affine recurrence solved by a scan.
        rhs = known + jnp.roll(center, -1)

        def x_body(v_prev, i):
            v = b * (v_prev + rhs[i])
            return v, v

        idx = jnp.arange(1, nx - 1)
        _, interior = lax.scan(x_body, center[0], idx)
        new_line = center.at[1 : nx - 1].set(interior)
        return new_line, new_line

    # scan over interior lines; carry = previously updated line (j-1).
    js = jnp.arange(1, ny - 1)
    _, lines = lax.scan(line_update, u_center[0], js)
    return u_center.at[1 : ny - 1].set(lines)


def gauss_seidel_sweep(u: jnp.ndarray) -> jnp.ndarray:
    """One full lexicographic GS sweep (Laplace), jnp reference.

    Scans over interior z planes carrying the updated previous plane: plane
    ``k`` reads plane ``k-1`` NEW (the carry) and plane ``k+1`` OLD (still
    unmodified in ``u``) — exactly the in-place semantics of the paper's
    listing. The numpy oracle below proves this in the test suite.
    """
    nz = u.shape[0]

    def u_dyn(a, k):
        return lax.dynamic_index_in_dim(a, k, axis=0, keepdims=False)

    def plane_body(carry, k):
        new_plane = gauss_seidel_plane(carry, u_dyn(u, k), u_dyn(u, k + 1))
        return new_plane, new_plane

    ks = jnp.arange(1, nz - 1)
    _, planes = lax.scan(plane_body, u[0], ks)
    return u.at[1 : nz - 1].set(planes)


def gauss_seidel_sweeps(u: jnp.ndarray, n: int) -> jnp.ndarray:
    """``n`` consecutive lexicographic GS sweeps."""

    def body(carry, _):
        return gauss_seidel_sweep(carry), None

    out, _ = lax.scan(body, u, None, length=n)
    return out


def jacobi_step_np(u: np.ndarray, f: np.ndarray, h2: float) -> np.ndarray:
    """Triple-loop transliteration of the paper's Jacobi listing (Sec. 3)."""
    nz, ny, nx = u.shape
    dst = u.copy()
    for k in range(1, nz - 1):
        for j in range(1, ny - 1):
            for i in range(1, nx - 1):
                dst[k, j, i] = ONE_SIXTH * (
                    u[k, j, i - 1]
                    + u[k, j, i + 1]
                    + u[k, j - 1, i]
                    + u[k, j + 1, i]
                    + u[k - 1, j, i]
                    + u[k + 1, j, i]
                    + h2 * f[k, j, i]
                )
    return dst


def gauss_seidel_sweep_np(u: np.ndarray) -> np.ndarray:
    """Triple-loop transliteration of the paper's Gauss-Seidel listing."""
    nz, ny, nx = u.shape
    v = u.copy()
    for k in range(1, nz - 1):
        for j in range(1, ny - 1):
            for i in range(1, nx - 1):
                v[k, j, i] = ONE_SIXTH * (
                    v[k, j, i - 1]
                    + v[k, j, i + 1]
                    + v[k, j - 1, i]
                    + v[k, j + 1, i]
                    + v[k - 1, j, i]
                    + v[k + 1, j, i]
                )
    return v
