"""Fused temporal-wavefront Jacobi kernel — the paper's contribution on TPU.

The paper's multicore wavefront (Sec. 4, Fig. 6) runs ``t`` time-shifted
sweeps through the grid so that a plane updated by thread ``s`` is consumed
by thread ``s+1`` straight out of the shared outer-level cache; only the
first sweep reads and only the last sweep writes main memory, cutting DRAM
traffic per ``t`` updates from ``t·(8+8) B`` to ``16 B`` per lattice site.

On a TPU there are no cache-sharing cores, but there is the same two-level
bandwidth cliff: VMEM (~TB/s) vs HBM. The faithful adaptation is *kernel
fusion over time*: one Pallas kernel computes the ``t``-times-updated value
of each output plane while every intermediate value lives in VMEM
(registers/scratch of the kernel instance). The rolling window of
``2t + 1`` source planes that the paper keeps in L3 becomes the kernel's
input footprint, expressed with ``2t + 1`` shifted ``BlockSpec`` windows
over a z-padded copy of the source — the ``BlockSpec`` index maps ARE the
wavefront schedule (HBM→VMEM plane streaming), exactly the role the
thread-group scheduling played on the CPU.

VMEM footprint per grid step: ``(2t+1) · ny · nx · 8 B`` for the stack plus
``(2t+1)`` rhs planes — e.g. t=4, 200×200 planes → 9·0.32 MB ·2 ≈ 5.8 MB,
comfortably inside 16 MB VMEM; see DESIGN.md §Perf for the full table.

Correctness contract (pytest-enforced): for every t ≥ 1,
``wavefront_steps(u, f, h2, t) == ref.jacobi_steps(u, f, h2, t)`` to fp64
round-off.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import ONE_SIXTH


def _wavefront_kernel(*refs, t: int, nz: int, h2: float):
    """Compute the t-step Jacobi value of one output plane.

    ``refs`` = 2t+1 source-plane windows, 2t+1 rhs-plane windows, out ref.
    The stack of 2t+1 planes is updated in place (functionally) t times;
    entry ``m`` has global z index ``g = k + 1 - t + m`` (k = program id),
    clamped copies beyond the physical domain are masked out and never
    consumed by a live entry.
    """
    n = 2 * t + 1
    u_refs, f_refs, o_ref = refs[:n], refs[n : 2 * n], refs[2 * n]
    stack = jnp.concatenate([r[...] for r in u_refs], axis=0)   # (2t+1, ny, nx)
    rhs = jnp.concatenate([r[...] for r in f_refs], axis=0)
    _, ny, nx = stack.shape

    k = pl.program_id(0)
    g = k + 1 - t + jnp.arange(n)                       # global z per entry
    mask_z = ((g >= 1) & (g <= nz - 2))[1:-1, None, None]
    y = jax.lax.broadcasted_iota(jnp.int32, (n - 2, ny, nx), 1)
    x = jax.lax.broadcasted_iota(jnp.int32, (n - 2, ny, nx), 2)
    interior = mask_z & (y > 0) & (y < ny - 1) & (x > 0) & (x < nx - 1)

    for _step in range(t):
        center = stack[1:-1]
        nbr = (
            stack[:-2]
            + stack[2:]
            + jnp.roll(center, 1, axis=1)
            + jnp.roll(center, -1, axis=1)
            + jnp.roll(center, 1, axis=2)
            + jnp.roll(center, -1, axis=2)
        )
        upd = ONE_SIXTH * (nbr + h2 * rhs[1:-1])
        new_center = jnp.where(interior, upd, center)
        stack = jnp.concatenate([stack[:1], new_center, stack[-1:]], axis=0)

    o_ref[...] = stack[t : t + 1]


def wavefront_steps(u: jnp.ndarray, f: jnp.ndarray, h2: float, t: int) -> jnp.ndarray:
    """``t`` fused Jacobi updates with all intermediates VMEM-resident.

    Equivalent to ``ref.jacobi_steps(u, f, h2, t)`` but with a single pass
    over the grid — the TPU rendering of the paper's thread-group wavefront
    with temporal blocking factor ``t``.
    """
    if t < 1:
        return u
    nz, ny, nx = u.shape
    if nz < 3:
        return u
    n = 2 * t + 1
    plane = (1, ny, nx)
    # Replicate the Dirichlet boundary planes t deep so every window is in
    # range; the replicas are masked inside the kernel (g outside [1,nz-2]).
    pad_u = jnp.concatenate(
        [jnp.broadcast_to(u[:1], (t, ny, nx)), u, jnp.broadcast_to(u[-1:], (t, ny, nx))],
        axis=0,
    )
    pad_f = jnp.concatenate(
        [jnp.broadcast_to(f[:1], (t, ny, nx)), f, jnp.broadcast_to(f[-1:], (t, ny, nx))],
        axis=0,
    )
    # Window for output plane k+1 occupies padded z indices [k+1, k+1+2t].
    in_specs = [
        pl.BlockSpec(plane, functools.partial(lambda k, m: (k + 1 + m, 0, 0), m=m))
        for m in range(n)
    ] * 2
    interior = pl.pallas_call(
        functools.partial(_wavefront_kernel, t=t, nz=nz, h2=h2),
        grid=(nz - 2,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(plane, lambda k: (k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nz - 2, ny, nx), u.dtype),
        interpret=True,
    )(*([pad_u] * n), *([pad_f] * n))
    return jnp.concatenate([u[:1], interior, u[-1:]], axis=0)


def vmem_footprint_bytes(ny: int, nx: int, t: int, dtype_bytes: int = 8) -> int:
    """Static VMEM footprint estimate of one kernel instance (DESIGN §Perf)."""
    planes = 2 * (2 * t + 1)          # source stack + rhs stack
    return planes * ny * nx * dtype_bytes


def max_temporal_depth(ny: int, nx: int, vmem_bytes: int = 16 * 2**20) -> int:
    """Largest blocking factor t whose rolling window fits VMEM."""
    t = 0
    while vmem_footprint_bytes(ny, nx, t + 1) <= vmem_bytes:
        t += 1
    return t
