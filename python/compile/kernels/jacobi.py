"""Pallas implementation of the 3D 7-point Jacobi smoother (L1 hot-spot).

The paper's line-update kernel (Sec. 3, Fig. 2) maps a 7-point stencil onto
five read streams plus one write stream; its cache-friendliness comes from
holding three z-planes in the outer cache level. The Pallas translation
keeps exactly that structure:

* the grid iterates over interior z-planes (the wavefront position),
* three ``BlockSpec``s bring the ``k-1``, ``k``, ``k+1`` planes of the
  source array into VMEM (the analog of the three planes resident in L3),
* the in-plane neighbor accesses are vectorized rolls — on a real TPU these
  are VPU shifts inside VMEM, the analog of the paper's SIMD-ized line
  update.

``interpret=True`` everywhere: the CPU PJRT backend cannot execute Mosaic
custom-calls, so the kernels are lowered through the Pallas interpreter to
plain HLO (see /opt/xla-example/README.md). Correctness is asserted against
:mod:`compile.kernels.ref` by the pytest suite.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import ONE_SIXTH


def _plane_kernel(zm_ref, zc_ref, zp_ref, f_ref, o_ref, *, h2: float):
    """Update one interior z-plane: out = 1/6 (6 neighbors + h²·f).

    Refs have block shape ``(1, ny, nx)``; y/x boundary points are copied
    from the center plane (Dirichlet).
    """
    zc = zc_ref[...]
    _, ny, nx = zc.shape
    nbr = (
        zm_ref[...]
        + zp_ref[...]
        + jnp.roll(zc, 1, axis=1)
        + jnp.roll(zc, -1, axis=1)
        + jnp.roll(zc, 1, axis=2)
        + jnp.roll(zc, -1, axis=2)
    )
    upd = ONE_SIXTH * (nbr + h2 * f_ref[...])
    y = jax.lax.broadcasted_iota(jnp.int32, (1, ny, nx), 1)
    x = jax.lax.broadcasted_iota(jnp.int32, (1, ny, nx), 2)
    interior = (y > 0) & (y < ny - 1) & (x > 0) & (x < nx - 1)
    o_ref[...] = jnp.where(interior, upd, zc)


def jacobi_step(u: jnp.ndarray, f: jnp.ndarray, h2: float) -> jnp.ndarray:
    """One out-of-place Jacobi update via the Pallas plane kernel.

    Grid over the ``nz - 2`` interior planes; boundary planes are copied
    through unchanged, matching :func:`compile.kernels.ref.jacobi_step`.
    """
    nz, ny, nx = u.shape
    if nz < 3:
        return u
    plane = (1, ny, nx)
    interior = pl.pallas_call(
        functools.partial(_plane_kernel, h2=h2),
        grid=(nz - 2,),
        in_specs=[
            pl.BlockSpec(plane, lambda k: (k, 0, 0)),      # z-1
            pl.BlockSpec(plane, lambda k: (k + 1, 0, 0)),  # z
            pl.BlockSpec(plane, lambda k: (k + 2, 0, 0)),  # z+1
            pl.BlockSpec(plane, lambda k: (k + 1, 0, 0)),  # f at z
        ],
        out_specs=pl.BlockSpec(plane, lambda k: (k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nz - 2, ny, nx), u.dtype),
        interpret=True,
    )(u, u, u, f)
    return jnp.concatenate([u[:1], interior, u[-1:]], axis=0)
