"""Pallas implementation of the lexicographic Gauss-Seidel plane update.

Gauss-Seidel is the hard case of the paper: the in-place update carries a
true dependency along all three axes, which rules out SIMD on the central
line and makes pipelining the bottleneck (Sec. 3). The paper's optimized
assembly kernel interleaves two updates to break register dependency
chains. The vector-hardware analog of that trick is to *solve* the x
recurrence instead of executing it serially: the line update

    v[i] = b · ( v[i-1] + known[i] )          (b = 1/6)

is a first-order affine recurrence ``v[i] = a·v[i-1] + c[i]``, which an
``associative_scan`` evaluates in O(log nx) depth on the VPU — the maximal
generalization of "interleave two updates" (interleaving by 2 halves the
chain; the scan reduces it to log). Lexicographic update *order* (and hence
bitwise semantics up to fp reassociation) is preserved: plane k consumes
plane k-1 NEW and plane k+1 OLD, line j consumes line j-1 NEW, exactly as
the paper's pipeline-parallel scheme (Fig. 5) requires.

The kernel operates on one z-plane; the L2 model composes planes with a
``lax.scan`` carrying the updated k-1 plane — the same producer/consumer
chain the rust coordinator implements with threads and barriers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .ref import ONE_SIXTH


def _affine_combine(f, g):
    """Compose affine maps: apply f first, then g. Pairs are (A, C): v ↦ A·v + C."""
    a1, c1 = f
    a2, c2 = g
    return a2 * a1, a2 * c1 + c2


def _gs_plane_kernel(prev_ref, cen_ref, nxt_ref, o_ref):
    """Lexicographic GS update of one interior plane (blocks = full plane)."""
    prev_new = prev_ref[...]   # plane k-1, already updated this sweep
    center = cen_ref[...]      # plane k, pre-sweep values
    nxt_old = nxt_ref[...]     # plane k+1, pre-sweep values
    ny, nx = center.shape
    b = ONE_SIXTH

    def line_update(prev_new_line, j):
        cen_j = center[j]
        # Terms known before the x recursion starts: new k-1 plane, old k+1
        # plane, new j-1 line, old j+1 line, old x+1 neighbor.
        known = prev_new[j] + nxt_old[j] + prev_new_line + center[j + 1]
        rhs = known + jnp.roll(cen_j, -1)
        # Affine recurrence v[i] = b·v[i-1] + b·rhs[i] on i = 1..nx-2,
        # seeded by the Dirichlet value v[0] = cen_j[0]. Solved by an
        # associative scan (the vectorized "dependency break").
        a = jnp.full((nx - 2,), b, center.dtype)
        c = b * rhs[1 : nx - 1]
        # Fold the seed into the first element so the scan is self-contained.
        c = c.at[0].add(b * cen_j[0])
        _, v = lax.associative_scan(_affine_combine, (a, c))
        new_line = cen_j.at[1 : nx - 1].set(v)
        return new_line, new_line

    js = jnp.arange(1, ny - 1)
    _, lines = lax.scan(line_update, center[0], js)
    o_ref[...] = center.at[1 : ny - 1].set(lines)


def gs_plane_update(
    prev_new: jnp.ndarray, center: jnp.ndarray, nxt_old: jnp.ndarray
) -> jnp.ndarray:
    """Update one interior plane via the Pallas kernel (whole-plane block)."""
    ny, nx = center.shape
    return pl.pallas_call(
        _gs_plane_kernel,
        out_shape=jax.ShapeDtypeStruct((ny, nx), center.dtype),
        interpret=True,
    )(prev_new, center, nxt_old)


def gs_sweep(u: jnp.ndarray) -> jnp.ndarray:
    """One full lexicographic GS sweep built from Pallas plane updates.

    The z scan carries the freshly updated k-1 plane while reading the
    still-old k and k+1 planes from ``u`` — in-place semantics without
    in-place buffers, mirroring the paper's pipelined plane chain.
    """
    nz = u.shape[0]

    def plane_body(carry, k):
        center = lax.dynamic_index_in_dim(u, k, axis=0, keepdims=False)
        nxt = lax.dynamic_index_in_dim(u, k + 1, axis=0, keepdims=False)
        new_plane = gs_plane_update(carry, center, nxt)
        return new_plane, new_plane

    ks = jnp.arange(1, nz - 1)
    _, planes = lax.scan(plane_body, u[0], ks)
    return u.at[1 : nz - 1].set(planes)


def gs_sweeps(u: jnp.ndarray, n: int) -> jnp.ndarray:
    """``n`` consecutive lexicographic GS sweeps."""

    def body(carry, _):
        return gs_sweep(carry), None

    out, _ = lax.scan(body, u, None, length=n)
    return out
