"""AOT lowering: JAX smoother graphs → HLO text artifacts for the rust runtime.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Every artifact is described in ``artifacts/manifest.json`` so the rust
runtime can discover shapes, dtypes, and static parameters without parsing
HLO. Usage::

    cd python && python -m compile.aot --out-dir ../artifacts [--small-only]

``make artifacts`` wraps this and is a no-op while inputs are unchanged.
"""

from __future__ import annotations

import argparse
import json
import os
from dataclasses import dataclass, field
from typing import Any, Callable

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402  (after x64 flag)
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

DTYPE = jnp.float64
H2 = 1.0  # unit grid spacing baked into the artifacts (h² = 1)


@dataclass
class Entry:
    """One AOT entry point: a traced function plus its example shapes."""

    name: str
    fn: Callable[..., Any]
    arg_shapes: list[tuple[int, ...]]
    params: dict[str, Any] = field(default_factory=dict)
    n_outputs: int = 1


def _spec(shape: tuple[int, ...]) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, DTYPE)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for the loader)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def entries(small_only: bool = False) -> list[Entry]:
    """The artifact catalog. 16³ entries serve fast tests, 40³ the examples."""
    cat: list[Entry] = []

    def grid_entries(n: int, iters: int, wf_t: int) -> list[Entry]:
        g = (n, n, n)
        return [
            Entry(
                f"jacobi_step_n{n}",
                lambda u, f: model.jacobi_smoother(u, f, H2, 1),
                [g, g],
                {"h2": H2, "iters": 1, "scheme": "jacobi"},
            ),
            Entry(
                f"jacobi_sweep_n{n}_it{iters}",
                lambda u, f, it=iters: model.jacobi_smoother(u, f, H2, it),
                [g, g],
                {"h2": H2, "iters": iters, "scheme": "jacobi"},
            ),
            Entry(
                f"jacobi_wavefront_n{n}_t{wf_t}",
                lambda u, f, t=wf_t: model.jacobi_wavefront_smoother(u, f, H2, t, 1),
                [g, g],
                {"h2": H2, "iters": wf_t, "wavefront_t": wf_t, "scheme": "jacobi"},
            ),
            Entry(
                f"gs_sweep_n{n}",
                lambda u: model.gs_smoother(u, 1),
                [g],
                {"iters": 1, "scheme": "gauss_seidel"},
            ),
            Entry(
                f"jacobi_smooth_residual_n{n}_it{iters}",
                lambda u, f, it=iters: model.jacobi_smooth_and_residual(u, f, H2, it),
                [g, g],
                {"h2": H2, "iters": iters, "scheme": "jacobi"},
                n_outputs=2,
            ),
            Entry(
                f"gs_smooth_residual_n{n}_it{iters}",
                lambda u, it=iters: model.gs_smooth_and_residual(u, it),
                [g],
                {"iters": iters, "scheme": "gauss_seidel"},
                n_outputs=2,
            ),
            Entry(
                f"residual_n{n}",
                lambda u, f: model.residual_norm(u, f, H2),
                [g, g],
                {"h2": H2, "scheme": "residual"},
            ),
        ]

    cat += grid_entries(16, iters=4, wf_t=2)
    if not small_only:
        cat += grid_entries(40, iters=8, wf_t=4)
    return cat


def build(out_dir: str, small_only: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict[str, Any] = {"dtype": "f64", "artifacts": []}
    for e in entries(small_only):
        specs = [_spec(s) for s in e.arg_shapes]
        lowered = jax.jit(e.fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{e.name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as fh:
            fh.write(text)
        manifest["artifacts"].append(
            {
                "name": e.name,
                "file": fname,
                "inputs": [{"shape": list(s), "dtype": "f64"} for s in e.arg_shapes],
                "n_outputs": e.n_outputs,
                "params": e.params,
            }
        )
        print(f"  lowered {e.name}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {out_dir}")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--out", dest="out_dir_compat", default=None, help=argparse.SUPPRESS)
    p.add_argument("--small-only", action="store_true")
    args = p.parse_args()
    out_dir = args.out_dir
    if args.out_dir_compat:  # legacy single-file arg from the scaffold Makefile
        out_dir = os.path.dirname(args.out_dir_compat) or "."
    build(out_dir, args.small_only)


if __name__ == "__main__":
    main()
