"""Pallas Gauss-Seidel kernel vs references.

The associative-scan line solver must reproduce the strictly sequential
lexicographic recursion of the paper's listing to fp64 round-off, and the
z-plane scan must honour in-place semantics (new k-1, old k+1).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gauss_seidel as gsk
from compile.kernels import ref

dims = st.integers(min_value=3, max_value=10)


@settings(max_examples=20, deadline=None)
@given(nz=dims, ny=dims, nx=dims, seed=st.integers(0, 2**31))
def test_pallas_gs_sweep_matches_listing(nz, ny, nx, seed):
    rng = np.random.default_rng(seed)
    u = rng.standard_normal((nz, ny, nx))
    got = np.asarray(gsk.gs_sweep(jnp.asarray(u)))
    want = ref.gauss_seidel_sweep_np(u)
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-12)


def test_plane_update_matches_ref_plane(rng):
    prev_new = rng.standard_normal((8, 9))
    center = rng.standard_normal((8, 9))
    nxt = rng.standard_normal((8, 9))
    got = np.asarray(
        gsk.gs_plane_update(jnp.asarray(prev_new), jnp.asarray(center), jnp.asarray(nxt))
    )
    want = np.asarray(
        ref.gauss_seidel_plane(jnp.asarray(prev_new), jnp.asarray(center), jnp.asarray(nxt))
    )
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-12)


def test_multi_sweep_composes(rng):
    u = jnp.asarray(rng.standard_normal((6, 6, 6)))
    two = gsk.gs_sweeps(u, 2)
    one_one = gsk.gs_sweep(gsk.gs_sweep(u))
    np.testing.assert_allclose(np.asarray(two), np.asarray(one_one), atol=1e-15)


def test_sweep_reduces_laplace_residual(rng):
    u = jnp.asarray(rng.standard_normal((10, 10, 10)))
    zero = jnp.zeros_like(u)
    r0 = float(ref.l2_norm(ref.residual(u, zero, 1.0)))
    r1 = float(ref.l2_norm(ref.residual(gsk.gs_sweep(u), zero, 1.0)))
    assert r1 < r0


def test_update_order_is_lexicographic(rng):
    """GS must differ from Jacobi on the same data (uses fresh values)."""
    u = rng.standard_normal((5, 5, 5))
    gs = np.asarray(gsk.gs_sweep(jnp.asarray(u)))
    jac = np.asarray(ref.jacobi_step(jnp.asarray(u), jnp.zeros((5, 5, 5)), 0.0))
    assert not np.allclose(gs, jac)
    # but the very first interior point sees only old values => identical
    np.testing.assert_allclose(gs[1, 1, 1], jac[1, 1, 1], atol=1e-15)
