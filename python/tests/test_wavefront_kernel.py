"""Fused temporal-wavefront kernel ≡ t sequential Jacobi steps.

This is the core correctness claim of the TPU adaptation (DESIGN.md
§Hardware-Adaptation): temporal fusion must be *exactly* the composition of
t reference steps, for every t and every shape, including the boundary
windows where the rolling stack is fed clamped replica planes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, wavefront

dims = st.integers(min_value=3, max_value=10)


@settings(max_examples=20, deadline=None)
@given(nz=dims, ny=dims, nx=dims, t=st.integers(1, 5), seed=st.integers(0, 2**31))
def test_wavefront_matches_t_ref_steps(nz, ny, nx, t, seed):
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.standard_normal((nz, ny, nx)))
    f = jnp.asarray(rng.standard_normal((nz, ny, nx)))
    got = np.asarray(wavefront.wavefront_steps(u, f, 1.0, t))
    want = np.asarray(ref.jacobi_steps(u, f, 1.0, t))
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-12)


@pytest.mark.parametrize("t", [1, 2, 3, 4, 6, 8])
def test_wavefront_depths_on_fixed_grid(rng, t):
    """The paper's blocking factors (2…8 threads per group) as fusion depths."""
    u = jnp.asarray(rng.standard_normal((12, 9, 11)))
    f = jnp.asarray(rng.standard_normal((12, 9, 11)))
    got = np.asarray(wavefront.wavefront_steps(u, f, 0.5, t))
    want = np.asarray(ref.jacobi_steps(u, f, 0.5, t))
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-12)


def test_t_zero_is_identity(rng):
    u = jnp.asarray(rng.standard_normal((5, 5, 5)))
    f = jnp.zeros_like(u)
    np.testing.assert_array_equal(
        np.asarray(wavefront.wavefront_steps(u, f, 1.0, 0)), np.asarray(u)
    )


def test_small_z_window_dominated(rng):
    """nz=3: single interior plane, windows are mostly clamped replicas."""
    u = jnp.asarray(rng.standard_normal((3, 6, 6)))
    f = jnp.asarray(rng.standard_normal((3, 6, 6)))
    for t in (1, 2, 4):
        got = np.asarray(wavefront.wavefront_steps(u, f, 1.0, t))
        want = np.asarray(ref.jacobi_steps(u, f, 1.0, t))
        np.testing.assert_allclose(got, want, atol=1e-12)


def test_vmem_footprint_model():
    """Footprint accounting used by DESIGN.md §Perf must be monotone and sane."""
    assert wavefront.vmem_footprint_bytes(200, 200, 4) == 2 * 9 * 200 * 200 * 8
    assert wavefront.vmem_footprint_bytes(100, 100, 2) < wavefront.vmem_footprint_bytes(
        100, 100, 3
    )
    t_max = wavefront.max_temporal_depth(200, 200)
    assert t_max >= 1
    assert wavefront.vmem_footprint_bytes(200, 200, t_max) <= 16 * 2**20
    assert wavefront.vmem_footprint_bytes(200, 200, t_max + 1) > 16 * 2**20
