"""AOT pipeline: catalog structure and HLO text emission."""

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


def test_catalog_structure():
    cat = aot.entries(small_only=True)
    names = [e.name for e in cat]
    assert len(names) == len(set(names)), "artifact names must be unique"
    assert "jacobi_step_n16" in names
    assert "gs_sweep_n16" in names
    for e in cat:
        assert e.arg_shapes, e.name
        for s in e.arg_shapes:
            assert len(s) == 3
        assert e.n_outputs in (1, 2)


def test_full_catalog_superset_of_small():
    small = {e.name for e in aot.entries(small_only=True)}
    full = {e.name for e in aot.entries(small_only=False)}
    assert small < full
    assert any("n40" in n for n in full)


def test_hlo_text_emission_smoke():
    spec = jax.ShapeDtypeStruct((8, 8, 8), jnp.float64)
    lowered = jax.jit(lambda u, f: model.jacobi_smoother(u, f, 1.0, 2)).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    assert "f64[8,8,8]" in text
    # return_tuple=True: the root must be a tuple for the rust loader
    assert "tuple" in text


def test_hlo_is_iteration_count_stable():
    """Scan keeps HLO size O(1) in iteration count (DESIGN §Perf L2)."""
    spec = jax.ShapeDtypeStruct((8, 8, 8), jnp.float64)

    def size(n):
        lowered = jax.jit(lambda u, f: model.jacobi_smoother(u, f, 1.0, n)).lower(spec, spec)
        return len(aot.to_hlo_text(lowered))

    assert size(64) < 1.3 * size(2)


@pytest.mark.parametrize("bad", ["--out-dir"])
def test_cli_entrypoint_exists(bad):
    # main() is argparse-based; just assert the module exposes it
    assert callable(aot.main)
