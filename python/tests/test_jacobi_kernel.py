"""Pallas Jacobi kernel vs the reference oracle (hypothesis shape sweep)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import jacobi, ref

dims = st.integers(min_value=3, max_value=12)


def _arrays(rng, shape):
    return rng.standard_normal(shape), rng.standard_normal(shape)


@settings(max_examples=25, deadline=None)
@given(nz=dims, ny=dims, nx=dims, h2=st.floats(0.0, 4.0), seed=st.integers(0, 2**31))
def test_pallas_jacobi_matches_ref(nz, ny, nx, h2, seed):
    rng = np.random.default_rng(seed)
    u, f = _arrays(rng, (nz, ny, nx))
    got = np.asarray(jacobi.jacobi_step(jnp.asarray(u), jnp.asarray(f), h2))
    want = np.asarray(ref.jacobi_step(jnp.asarray(u), jnp.asarray(f), h2))
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-13)


@pytest.mark.parametrize("shape", [(3, 3, 3), (16, 8, 4), (5, 20, 7)])
def test_pallas_jacobi_matches_paper_listing(rng, shape):
    u = rng.standard_normal(shape)
    f = rng.standard_normal(shape)
    got = np.asarray(jacobi.jacobi_step(jnp.asarray(u), jnp.asarray(f), 1.3))
    want = ref.jacobi_step_np(u, f, 1.3)
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-13)


def test_degenerate_z_is_identity(rng):
    """nz < 3 has no interior planes: the update is the identity."""
    u = jnp.asarray(rng.standard_normal((2, 5, 5)))
    f = jnp.zeros_like(u)
    np.testing.assert_array_equal(np.asarray(jacobi.jacobi_step(u, f, 1.0)), np.asarray(u))


def test_dtype_preserved(rng):
    u = jnp.asarray(rng.standard_normal((4, 4, 4)), dtype=jnp.float32)
    f = jnp.zeros_like(u)
    out = jacobi.jacobi_step(u, f, 1.0)
    assert out.dtype == jnp.float32


def test_jitted_equals_eager(rng):
    import jax

    u = jnp.asarray(rng.standard_normal((6, 6, 6)))
    f = jnp.asarray(rng.standard_normal((6, 6, 6)))
    eager = jacobi.jacobi_step(u, f, 2.0)
    jitted = jax.jit(lambda a, b: jacobi.jacobi_step(a, b, 2.0))(u, f)
    np.testing.assert_allclose(np.asarray(eager), np.asarray(jitted), atol=0)
