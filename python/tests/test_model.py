"""L2 model graphs: smoother composition, wavefront equivalence, residuals."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


@pytest.fixture
def problem(rng):
    u = jnp.asarray(rng.standard_normal((8, 8, 8)))
    f = jnp.asarray(rng.standard_normal((8, 8, 8)))
    return u, f


def test_jacobi_smoother_equals_ref_steps(problem):
    u, f = problem
    got = model.jacobi_smoother(u, f, 1.0, 5)
    want = ref.jacobi_steps(u, f, 1.0, 5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-13)


@pytest.mark.parametrize("t,n_outer", [(2, 3), (3, 2), (4, 1), (1, 4)])
def test_wavefront_smoother_equals_plain_smoother(problem, t, n_outer):
    """t·n_outer fused updates ≡ t·n_outer plain updates — the paper's
    headline invariant: temporal blocking changes traffic, not numerics."""
    u, f = problem
    fused = model.jacobi_wavefront_smoother(u, f, 1.0, t, n_outer)
    plain = model.jacobi_smoother(u, f, 1.0, t * n_outer)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(plain), atol=1e-11)


def test_gs_smoother_equals_listing(rng):
    u = rng.standard_normal((6, 6, 6))
    got = np.asarray(model.gs_smoother(jnp.asarray(u), 2))
    want = ref.gauss_seidel_sweep_np(ref.gauss_seidel_sweep_np(u))
    np.testing.assert_allclose(got, want, atol=1e-12)


def test_smooth_and_residual_outputs(problem):
    u, f = problem
    out, rn = model.jacobi_smooth_and_residual(u, f, 1.0, 3)
    want_out = ref.jacobi_steps(u, f, 1.0, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want_out), atol=1e-13)
    want_rn = ref.l2_norm(ref.residual(want_out, f, 1.0))
    np.testing.assert_allclose(float(rn), float(want_rn), rtol=1e-12)


def test_gs_smooth_and_residual_decreases(rng):
    u = jnp.asarray(rng.standard_normal((8, 8, 8)))
    _, r1 = model.gs_smooth_and_residual(u, 1)
    _, r3 = model.gs_smooth_and_residual(u, 3)
    assert float(r3) < float(r1)


def test_residual_norm_nonnegative(problem):
    u, f = problem
    assert float(model.residual_norm(u, f, 1.0)) >= 0.0


def test_graphs_are_jittable(problem):
    u, f = problem
    j = jax.jit(lambda a, b: model.jacobi_wavefront_smoother(a, b, 1.0, 2, 2))
    eager = model.jacobi_wavefront_smoother(u, f, 1.0, 2, 2)
    np.testing.assert_allclose(np.asarray(j(u, f)), np.asarray(eager), atol=1e-13)


def test_scan_keeps_hlo_size_constant(problem):
    """DESIGN §Perf L2: lowered HLO must be O(1) in n_iter (scan, no unroll)."""
    u, f = problem
    spec = jax.ShapeDtypeStruct(u.shape, u.dtype)

    def size(n):
        low = jax.jit(lambda a, b, n=n: model.jacobi_smoother(a, b, 1.0, n)).lower(
            spec, spec
        )
        return len(low.compiler_ir("stablehlo").__str__())

    s2, s32 = size(2), size(32)
    assert s32 < 1.2 * s2, (s2, s32)
