"""Internal consistency of the reference oracles.

The jnp-vectorized references must agree with the triple-loop numpy
transliterations of the paper's C listings — this anchors everything else
in the repo to the paper's exact update equations.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref


def _rand(rng, shape):
    return rng.standard_normal(shape)


SHAPES = [(3, 3, 3), (4, 5, 6), (8, 7, 9), (6, 6, 6), (3, 8, 4)]


@pytest.mark.parametrize("shape", SHAPES)
def test_jacobi_jnp_matches_paper_listing(rng, shape):
    u = _rand(rng, shape)
    f = _rand(rng, shape)
    got = np.asarray(ref.jacobi_step(jnp.asarray(u), jnp.asarray(f), 0.7))
    want = ref.jacobi_step_np(u, f, 0.7)
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-14)


@pytest.mark.parametrize("shape", SHAPES)
def test_gs_jnp_matches_paper_listing(rng, shape):
    u = _rand(rng, shape)
    got = np.asarray(ref.gauss_seidel_sweep(jnp.asarray(u)))
    want = ref.gauss_seidel_sweep_np(u)
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-13)


def test_jacobi_boundary_untouched(rng):
    u = _rand(rng, (6, 6, 6))
    f = _rand(rng, (6, 6, 6))
    out = np.asarray(ref.jacobi_step(jnp.asarray(u), jnp.asarray(f), 1.0))
    np.testing.assert_array_equal(out[0], u[0])
    np.testing.assert_array_equal(out[-1], u[-1])
    np.testing.assert_array_equal(out[:, 0], u[:, 0])
    np.testing.assert_array_equal(out[:, -1], u[:, -1])
    np.testing.assert_array_equal(out[:, :, 0], u[:, :, 0])
    np.testing.assert_array_equal(out[:, :, -1], u[:, :, -1])


def test_gs_boundary_untouched(rng):
    u = _rand(rng, (6, 7, 5))
    out = np.asarray(ref.gauss_seidel_sweep(jnp.asarray(u)))
    np.testing.assert_array_equal(out[0], u[0])
    np.testing.assert_array_equal(out[-1], u[-1])
    np.testing.assert_array_equal(out[:, 0], u[:, 0])
    np.testing.assert_array_equal(out[:, -1], u[:, -1])
    np.testing.assert_array_equal(out[:, :, 0], u[:, :, 0])
    np.testing.assert_array_equal(out[:, :, -1], u[:, :, -1])


def test_jacobi_fixed_point_of_harmonic(rng):
    """A discrete-harmonic field (Laplace, f=0) is a Jacobi fixed point."""
    nz, ny, nx = 6, 6, 6
    z, y, x = np.meshgrid(
        np.arange(nz), np.arange(ny), np.arange(nx), indexing="ij"
    )
    u = (x + 2.0 * y - 3.0 * z).astype(np.float64)  # linear => harmonic
    out = np.asarray(ref.jacobi_step(jnp.asarray(u), jnp.zeros((nz, ny, nx)), 1.0))
    np.testing.assert_allclose(out, u, atol=1e-13)


def test_gs_fixed_point_of_harmonic():
    nz, ny, nx = 6, 6, 6
    z, y, x = np.meshgrid(
        np.arange(nz), np.arange(ny), np.arange(nx), indexing="ij"
    )
    u = (x - y + 0.5 * z).astype(np.float64)
    out = np.asarray(ref.gauss_seidel_sweep(jnp.asarray(u)))
    np.testing.assert_allclose(out, u, atol=1e-13)


def test_residual_zero_for_exact_solution():
    nz = 6
    z, y, x = np.meshgrid(
        np.arange(nz), np.arange(nz), np.arange(nz), indexing="ij"
    )
    u = (x * 1.0 + y * 2.0 + z * 3.0).astype(np.float64)
    r = np.asarray(ref.residual(jnp.asarray(u), jnp.zeros_like(jnp.asarray(u)), 1.0))
    np.testing.assert_allclose(r, 0.0, atol=1e-12)


def test_gs_converges_on_laplace(rng):
    """Repeated GS sweeps must reduce the Laplace residual monotonically."""
    u = jnp.asarray(rng.standard_normal((10, 10, 10)))
    zero = jnp.zeros_like(u)
    norms = []
    cur = u
    for _ in range(5):
        cur = ref.gauss_seidel_sweep(cur)
        norms.append(float(ref.l2_norm(ref.residual(cur, zero, 1.0))))
    assert all(b < a for a, b in zip(norms, norms[1:]))


def test_jacobi_steps_composes(rng):
    u = jnp.asarray(rng.standard_normal((5, 5, 5)))
    f = jnp.asarray(rng.standard_normal((5, 5, 5)))
    a = ref.jacobi_steps(u, f, 1.0, 3)
    b = ref.jacobi_step(ref.jacobi_step(ref.jacobi_step(u, f, 1.0), f, 1.0), f, 1.0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0)
