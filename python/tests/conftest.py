"""Shared pytest fixtures: enable x64 before any kernel import."""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(12345)
