"""Artifact catalog sanity: manifest ↔ files ↔ declared shapes.

These tests only run when ``make artifacts`` has produced the catalog;
they guard the contract the rust runtime relies on.
"""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


def _manifest():
    with open(os.path.join(ART, "manifest.json")) as fh:
        return json.load(fh)


def test_manifest_lists_existing_files():
    m = _manifest()
    assert m["dtype"] == "f64"
    assert len(m["artifacts"]) >= 7
    for a in m["artifacts"]:
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), a["file"]
        assert os.path.getsize(path) > 100


def test_artifacts_are_hlo_text():
    m = _manifest()
    for a in m["artifacts"]:
        with open(os.path.join(ART, a["file"])) as fh:
            head = fh.read(4096)
        assert "HloModule" in head, a["file"]
        assert "ENTRY" in open(os.path.join(ART, a["file"])).read(), a["file"]


def test_declared_shapes_appear_in_hlo():
    m = _manifest()
    for a in m["artifacts"]:
        text = open(os.path.join(ART, a["file"])).read()
        for inp in a["inputs"]:
            dims = ",".join(str(d) for d in inp["shape"])
            assert f"f64[{dims}]" in text, (a["name"], dims)


def test_catalog_covers_both_schemes_and_sizes():
    m = _manifest()
    names = {a["name"] for a in m["artifacts"]}
    for required in [
        "jacobi_step_n16",
        "gs_sweep_n16",
        "jacobi_wavefront_n16_t2",
        "residual_n16",
    ]:
        assert required in names
    schemes = {a["params"].get("scheme") for a in m["artifacts"]}
    assert {"jacobi", "gauss_seidel", "residual"} <= schemes


def test_wavefront_params_recorded():
    m = _manifest()
    wf = [a for a in m["artifacts"] if "wavefront" in a["name"]]
    assert wf
    for a in wf:
        assert a["params"]["wavefront_t"] >= 1
        assert a["params"]["iters"] == a["params"]["wavefront_t"]
